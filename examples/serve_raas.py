"""Serving example: batched requests under all five sparsity policies.

Shows the paper's "impossible trinity" table live: per-policy JCT,
decode throughput, KV memory, and (with a trained checkpoint) accuracy
on verifiable problems.

Run:  PYTHONPATH=src python examples/serve_raas.py
      (add --ckpt experiments/reasoner-100m/300.msgpack after running
       examples/train_reasoner.py for meaningful accuracy numbers)
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.config import ModelConfig, RaasConfig
from repro.data.pipeline import DataConfig, prompt_of, specials, verify_answer
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--ckpt", default="")
    p.add_argument("--budget", type=int, default=96)
    p.add_argument("--requests", type=int, default=8)
    args = p.parse_args()

    cfg = ModelConfig(name="reasoner-100m", arch_type="dense",
                      n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                      d_ff=2048, vocab_size=512, head_dim=64) \
        if args.ckpt else \
        ModelConfig(name="serve-demo", arch_type="dense", n_layers=4,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                    vocab_size=512, head_dim=32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        like = jax.eval_shape(lambda: {"params": params})
        params = ckpt.restore(args.ckpt, like)["params"]

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=192,
                    chain_steps=24)
    sp = specials(dc)

    print(f"{'policy':10s} {'JCT(s)':>8s} {'tok/s':>8s} "
          f"{'kv(MB)':>8s} {'acc':>5s}")
    for policy in ["dense", "quest", "raas", "h2o", "streaming"]:
        raas = RaasConfig(policy=policy, budget_tokens=args.budget,
                          page_size=8,
                          quest_topk_pages=args.budget // 8)
        eng = Engine(params, cfg, raas, batch_slots=4, max_seq=224,
                     max_prefill=16)
        reqs = []
        for i in range(args.requests):
            prompt, _ = prompt_of(dc, 90_000 + i)
            reqs.append(Request(uid=i, prompt=prompt,
                                max_new_tokens=180, eos_id=sp["EOS"]))
        t0 = time.time()
        done = serve(eng, reqs)
        jct = time.time() - t0
        acc = np.mean([verify_answer(dc, 90_000 + r.uid,
                                     np.asarray(r.output))
                       for r in done])
        # tok/s from the engine's true emitted-token counter
        print(f"{policy:10s} {jct:8.2f} {eng.tokens_emitted/jct:8.1f} "
              f"{eng.kv_cache_bytes()/1e6:8.2f} {acc:5.2f}")


if __name__ == "__main__":
    main()
