"""Quickstart: the RaaS algorithm in 60 lines.

Builds a small GQA transformer, prefill a short "question", decodes a
long "chain of thought" under the paper's RaaS policy, and shows the
O(L) memory property: the KV cache never grows past the budget while
dense decoding would keep every token.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RaasConfig
from repro.models import model as M

cfg = ModelConfig(name="quickstart", arch_type="dense", n_layers=4,
                  d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
                  vocab_size=512, head_dim=16, qk_norm=True)
params = M.init_params(jax.random.PRNGKey(0), cfg)

B, prefill_len, decode_len = 1, 24, 200
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, prefill_len), 0,
                            cfg.vocab_size)

for policy, budget in [("dense", 0), ("raas", 128)]:
    raas = RaasConfig(policy=policy, budget_tokens=max(budget, 128),
                      page_size=16)
    max_seq = prefill_len + decode_len + 1
    cache = M.init_model_cache(cfg, raas, B, max_seq_len=max_seq,
                               prefill_len=prefill_len)
    kv_mb = sum(c.attn.k_pages.nbytes + c.attn.v_pages.nbytes
                for c in cache.per_pos) / 1e6

    cache, logits = M.prefill(params, cfg, prompt,
                              jnp.full((B,), prefill_len), cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(lambda c, t, p: M.decode_step(params, cfg, t, p, c,
                                                 raas))
    for t in range(prefill_len, prefill_len + decode_len):
        cache, logits = step(cache, tok, jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    cached = int(cache.per_pos[0].attn.page_len[:, 0].sum())
    print(f"{policy:8s} | KV allocation {kv_mb:8.2f} MB | "
          f"tokens resident after {decode_len} decodes: "
          f"{int(cache.per_pos[0].attn.page_len.sum())} "
          f"(budget={raas.budget_tokens if policy != 'dense' else 'n/a'})")

print("\nRaaS holds memory at O(L) while dense grows O(N) — "
      "same decode loop, one config flag.")
