"""End-to-end driver: train a ~100M-param reasoner for a few hundred
steps on the synthetic chain-of-thought corpus, checkpoint it, then
serve held-out problems under RaaS vs Dense and report accuracy.

This is the full substrate in one script: data pipeline -> AdamW ->
remat'd scan model -> checkpoint -> continuous-batching engine with
the paper's policy.

Run:  PYTHONPATH=src python examples/train_reasoner.py [--steps 300]
(~100M params is the default; use --small for a fast demo.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.config import ModelConfig, RaasConfig, RunConfig
from repro.data.pipeline import DataConfig, batches, prompt_of, specials, verify_answer
from repro.launch.train import make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--small", action="store_true",
                   help="4L/128d demo model instead of ~100M")
    p.add_argument("--eval-n", type=int, default=16)
    args = p.parse_args()

    if args.small:
        cfg = ModelConfig(name="reasoner-s", arch_type="dense",
                          n_layers=4, d_model=128, n_heads=4,
                          n_kv_heads=2, d_ff=256, vocab_size=512,
                          head_dim=32)
    else:
        # ~100M params: 12L x 768d, llama-style
        cfg = ModelConfig(name="reasoner-100m", arch_type="dense",
                          n_layers=12, d_model=768, n_heads=12,
                          n_kv_heads=4, d_ff=2048, vocab_size=512,
                          head_dim=64)
    print(f"model: {cfg.name}  params={cfg.n_params()/1e6:.1f}M")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=192,
                    chain_steps=24)
    run = RunConfig(arch=cfg.name, lr=3e-3, total_steps=args.steps,
                    warmup_steps=max(10, args.steps // 10))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, run))
    it = batches(dc, args.batch)
    t0 = time.time()
    for i in range(args.steps):
        b = next(it)
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"]),
                               "loss_mask": jnp.asarray(b["loss_mask"])})
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  {time.time()-t0:.0f}s",
                  flush=True)
    ckpt.save(f"experiments/{cfg.name}/{args.steps}.msgpack",
              {"params": params})

    # -- serve held-out problems under Dense vs RaaS ------------------------
    sp = specials(dc)
    for policy, budget in [("dense", 256), ("raas", 96)]:
        raas = RaasConfig(policy=policy, budget_tokens=budget,
                          page_size=8)
        eng = Engine(params, cfg, raas, batch_slots=4, max_seq=224,
                     max_prefill=16)
        reqs = []
        for i in range(args.eval_n):
            prompt, _ = prompt_of(dc, 90_000 + i)
            reqs.append(Request(uid=i, prompt=prompt,
                                max_new_tokens=180, eos_id=sp["EOS"]))
        t0 = time.time()
        done = serve(eng, reqs)
        acc = np.mean([verify_answer(dc, 90_000 + r.uid,
                                     np.asarray(r.output))
                       for r in done])
        print(f"{policy:6s} budget={budget:4d}  accuracy={acc:.2f}  "
              f"JCT={time.time()-t0:.1f}s  "
              f"kv={eng.kv_cache_bytes()/1e6:.1f}MB")


if __name__ == "__main__":
    main()
