"""Paper Fig. 9 proxy: RaaS accuracy vs alpha (and the top-r rule).

Small alpha -> every page keeps refreshing -> degenerates to FIFO;
large alpha -> nothing refreshes -> milestone pages die early.  The
paper recommends alpha ~ 1e-4, equivalently top-r 50%.
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import (accuracy_under_policy, policy_cfg,
                               trained_reasoner)

ALPHAS = [1e-6, 1e-4, 1e-2, 1e-1]
BUDGETS = [48, 96]


def run(n_eval: int = 12) -> Dict:
    params, cfg, dc = trained_reasoner()
    rows = []
    for budget in BUDGETS:
        for alpha in ALPHAS:
            raas = policy_cfg("raas", budget, alpha=alpha,
                              use_top_r=False)
            t0 = time.time()
            acc = accuracy_under_policy(params, cfg, dc, raas,
                                        n_eval=n_eval)
            us = (time.time() - t0) / n_eval * 1e6
            name = f"fig9/alpha{alpha:g}-b{budget}"
            print(f"{name},{us:.0f},acc={acc:.3f}", flush=True)
            rows.append({"alpha": alpha, "budget": budget, "acc": acc})
        # the paper's top-r=50% rule as comparison
        raas = policy_cfg("raas", budget, use_top_r=True, top_r=0.5)
        t0 = time.time()
        acc = accuracy_under_policy(params, cfg, dc, raas, n_eval=n_eval)
        us = (time.time() - t0) / n_eval * 1e6
        print(f"fig9/top_r50-b{budget},{us:.0f},acc={acc:.3f}",
              flush=True)
        rows.append({"alpha": "top_r", "budget": budget, "acc": acc})
    return {"rows": rows}


if __name__ == "__main__":
    run()
