"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Figures:
  fig6 — accuracy vs cache budget (5 policies)       [paper Fig. 6]
  fig7 — latency/memory vs decode length             [paper Fig. 7]
  fig8 — decoding lengths under tight budgets        [paper Fig. 8]
  fig9 — RaaS alpha sweep                            [paper Fig. 9]
  roofline — dry-run roofline terms per arch x shape [deliverable g]

``--quick`` trims eval counts for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="",
                   help="comma list: fig6,fig7,fig8,fig9,serving,roofline")
    p.add_argument("--quick", action="store_true")
    args = p.parse_args(argv)
    want = set(args.only.split(",")) if args.only else {
        "fig6", "fig7", "fig8", "fig9", "serving", "fidelity", "roofline"}

    n6 = 6 if args.quick else 16
    n8 = 4 if args.quick else 12
    n9 = 4 if args.quick else 12

    print("name,us_per_call,derived")
    t0 = time.time()
    if "fig7" in want:
        from benchmarks import fig7_latency_memory
        fig7_latency_memory.run()
    if "serving" in want:
        from benchmarks import serving_throughput
        serving_throughput.run(n_requests=6 if args.quick else 15)
    if "fig6" in want:
        from benchmarks import fig6_accuracy
        fig6_accuracy.run(n_eval=n6)
    if "fig8" in want:
        from benchmarks import fig8_decoding_length
        fig8_decoding_length.run(n_eval=n8)
    if "fig9" in want:
        from benchmarks import fig9_alpha
        fig9_alpha.run(n_eval=n9)
    if "fidelity" in want:
        from benchmarks import fidelity
        fidelity.run(n_eval=2 if args.quick else 4)
    if "roofline" in want:
        from benchmarks import roofline
        roofline.run()
    print(f"total,{(time.time()-t0)*1e6:.0f},done", file=sys.stderr)


if __name__ == "__main__":
    main()
