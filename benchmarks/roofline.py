"""Roofline table: aggregate the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun_all),
computes the three roofline terms, MODEL_FLOPS (6*N*D for dense /
6*N_active*D for MoE), the useful-compute ratio, and prints the
per-(arch x shape) table consumed by EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.config import INPUT_SHAPES, get_config

DRYRUN_DIR = "experiments/dryrun"


def model_flops(arch: str, shape: str) -> float:
    """6*N_active*D for train (fwd+bwd); 2*N_active*D per decoded token."""
    cfg = get_config(arch)
    seq, batch, kind = INPUT_SHAPES[shape]
    n_active = cfg.n_active_params()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * batch        # one token per sequence


def load_records(mesh: str = "16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok" and r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run() -> Dict:
    recs = load_records()
    if not recs:
        print("roofline/no-dryrun-artifacts,0,run repro.launch.dryrun_all")
        return {"rows": []}
    rows = []
    for r in recs:
        arch, shape = r["arch"], r["shape"]
        if r.get("policy") in ("dense", "quest") and shape == "decode_32k":
            tag = f"{arch}_{shape}_{r['policy']}"
        else:
            tag = f"{arch}_{shape}"
        mf = model_flops(arch, shape)
        dev = r["devices"]
        hlo_f = r["flops_per_device"] * dev
        ratio = mf / hlo_f if hlo_f else 0.0
        t = r["roofline"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        print(f"roofline/{tag},{total*1e6:.1f},"
              f"compute_s={t['compute_s']:.3e};"
              f"memory_s={t['memory_s']:.3e};"
              f"collective_s={t['collective_s']:.3e};"
              f"dominant={r['dominant']};useful_ratio={ratio:.2f}",
              flush=True)
        rows.append({"tag": tag, **t, "dominant": r["dominant"],
                     "model_flops": mf, "hlo_flops": hlo_f,
                     "useful_ratio": ratio})
    return {"rows": rows}


if __name__ == "__main__":
    run()
