"""Paper Fig. 6 proxy: accuracy vs cache budget, five policies.

The paper's claim: RaaS and Quest reach Dense accuracy at moderate
budgets, H2O and StreamingLLM collapse (milestone tokens discarded);
at very small budgets RaaS underperforms (budget eaten by pinned
prefill).  We reproduce the mechanism with the synthetic verifiable
reasoner (see benchmarks/common.py) — exact-match accuracy on held-out
problems under each policy x budget.
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import (accuracy_under_policy, policy_cfg,
                               reset_jit, trained_reasoner)

POLICIES = ["dense", "raas", "quest", "h2o", "streaming"]
BUDGETS = [32, 48, 64, 96, 128]


def run(n_eval: int = 16) -> Dict:
    params, cfg, dc = trained_reasoner()
    rows = []
    for policy in POLICIES:
        reset_jit()
        for budget in BUDGETS:
            if policy == "dense" and budget != BUDGETS[-1]:
                continue  # dense has no budget knob
            t0 = time.time()
            raas = policy_cfg(policy, budget)
            acc = accuracy_under_policy(params, cfg, dc, raas,
                                        n_eval=n_eval)
            dt = (time.time() - t0) / n_eval * 1e6
            name = f"fig6/{policy}-{budget}"
            print(f"{name},{dt:.0f},acc={acc:.3f}", flush=True)
            rows.append({"policy": policy, "budget": budget, "acc": acc})
    return {"rows": rows}


if __name__ == "__main__":
    run()
