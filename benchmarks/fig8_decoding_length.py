"""Paper Fig. 8 proxy: decoding lengths under each policy.

The paper shows that discarding milestone tokens (H2O/StreamingLLM at
tight budgets) makes the model lose the reasoning thread and decode
until the length limit, while Dense/Quest/RaaS terminate normally.  We
measure emitted tokens until EOS (capped) per policy on the trained
synthetic reasoner.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import (greedy_decode_with_policy, policy_cfg,
                               trained_reasoner)
from repro.data.pipeline import make_example, specials

POLICIES = ["dense", "raas", "quest", "h2o", "streaming"]
BUDGET = 48          # tight: pressure on milestone retention
MAX_NEW = 176


def _len_to_answer(dc, index: int, decoded: np.ndarray) -> int:
    """Tokens emitted until the first correct `A <gold>` pair; MAX_NEW
    if the model never states the right answer (lost the thread and
    re-reasons forever — the paper's Fig. 8 pathology)."""
    _, _, gold = make_example(dc, index)
    sp = specials(dc)
    d = np.asarray(decoded).ravel()
    for j in range(len(d) - 1):
        if d[j] == sp["A"] and d[j + 1] == gold:
            return j + 2
    return MAX_NEW


def run(n_eval: int = 12) -> Dict:
    params, cfg, dc = trained_reasoner()
    rows = []
    for policy in POLICIES:
        raas = policy_cfg(policy, BUDGET)
        lens = []
        t0 = time.time()
        for i in range(n_eval):
            dec, _, _ = greedy_decode_with_policy(
                params, cfg, dc, raas, 60_000 + i, max_new=MAX_NEW)
            lens.append(_len_to_answer(dc, 60_000 + i, dec))
        us = (time.time() - t0) / n_eval * 1e6
        mean_len = float(np.mean(lens))
        hit_cap = float(np.mean([l >= MAX_NEW for l in lens]))
        name = f"fig8/{policy}-{BUDGET}"
        print(f"{name},{us:.0f},mean_len_to_answer={mean_len:.1f};"
              f"never_answered={hit_cap:.2f}", flush=True)
        rows.append({"policy": policy, "mean_len": mean_len,
                     "hit_cap": hit_cap})
    return {"rows": rows}


if __name__ == "__main__":
    run()
