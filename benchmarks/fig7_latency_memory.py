"""Paper Fig. 7 proxy: per-step latency and KV memory vs decode length,
plus the serving-stack dispatch-overhead sweep.

Claims reproduced:
  * Dense decode step cost grows with N (O(N) per step, O(N^2) total);
    RaaS/Quest per-step cost is O(L), flat in N.
  * Dense and Quest KV memory grow linearly with N; RaaS plateaus at
    the budget L.
  * Fused multi-token decode: one jitted dispatch per K tokens —
    tokens/sec at K=1 vs K=8/16/32 quantifies the per-token dispatch +
    host-round-trip overhead the chunked engine removes (jnp backend).

Latency here is measured wall-clock on CPU for the *attention step*
shapes at growing cache sizes; memory is the exact static allocation
of each policy's cache — every array of it, including rep keys and
page metadata (which is the paper's point — it is static).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_MODEL, policy_cfg
from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core.attention import decode_attend
from repro.core.policy_base import get_policy
from repro.models import model as M

DECODE_LENS = [256, 512, 1024, 2048, 4096, 8192]
BUDGET = 512
CHUNK_KS = [1, 8, 16, 32]


def _bench_step(policy: str, n_ctx: int, iters: int = 20) -> Dict:
    cfg = BENCH_MODEL
    raas = policy_cfg(policy, BUDGET, page_size=16)
    n_slots = get_policy(policy).cache_slots(raas, n_ctx + iters + 1, 64)
    spec = pc.CacheSpec(n_slots, raas.page_size, cfg.n_kv_heads,
                        cfg.resolved_head_dim, jnp.float32)
    cache = pc.init_cache(spec, 1)
    rng = np.random.default_rng(0)
    KV, hd, H = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_heads
    # simulate a cache that has already absorbed n_ctx decode tokens
    k = jnp.asarray(rng.standard_normal((1, min(n_ctx, 64), KV, hd)),
                    jnp.float32)
    cache = pc.ingest_prefill(cache, k, k,
                              jnp.asarray([min(n_ctx, 64)]))
    step = jax.jit(lambda c, q, kn, vn: decode_attend(c, q, kn, vn, raas))
    q = jnp.asarray(rng.standard_normal((1, H, hd)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((1, KV, hd)), jnp.float32)
    # fill to n_ctx
    for _ in range(min(n_ctx, n_slots * raas.page_size // 2)):
        cache, _, _ = step(cache, q, kn, kn)
    jax.block_until_ready(cache.k_pages)
    t0 = time.perf_counter()
    for _ in range(iters):
        cache, ctx, _ = step(cache, q, kn, kn)
    jax.block_until_ready(ctx)
    us = (time.perf_counter() - t0) / iters * 1e6
    # full footprint: K/V pages + rep keys + per-page metadata
    kv_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
    return {"us_per_step": us, "kv_bytes": kv_bytes}


def _bench_chunked(k_steps: int, n_tokens: int = 128,
                   batch: int = 4) -> Dict:
    """End-to-end decode throughput of the fused ``decode_chunk`` at
    chunk length K: the K=1 row is the old one-dispatch-per-token
    engine loop (host argmax round-trip per token); larger K amortises
    dispatch + sync across the chunk."""
    cfg = BENCH_MODEL
    raas = policy_cfg("raas", BUDGET, page_size=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq = 64 + n_tokens + k_steps + 1
    cache = M.init_model_cache(cfg, raas, batch, max_seq, prefill_len=64)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 64)),
                       jnp.int32)
    cache, logits = jax.jit(
        lambda p, c, t, l: M.prefill(p, cfg, t, l, c))(
            params, cache, toks, jnp.full((batch,), 64, jnp.int32))

    chunk = jax.jit(
        lambda p, c, tok, pos, act, n, eos, mx: M.decode_chunk(
            p, cfg, c, tok, pos, act, n, eos, mx, raas,
            steps=k_steps, max_seq=max_seq),
        static_argnames=())
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.full((batch,), 64, jnp.int32)
    active = jnp.ones((batch,), bool)
    n_emitted = jnp.ones((batch,), jnp.int32)
    eos = jnp.full((batch,), -1, jnp.int32)
    mx = jnp.full((batch,), n_tokens + k_steps + 1, jnp.int32)

    def run_once(cache, token, pos, n_emitted):
        for _ in range(n_tokens // k_steps):
            cache, out = chunk(params, cache, token, pos, active,
                               n_emitted, eos, mx)
            # chunk boundary: the engine syncs here
            token = out.token
            pos, n_emitted = out.pos, out.n_emitted
            np.asarray(token)
        return cache, token

    run_once(cache, token, pos, n_emitted)          # compile
    t0 = time.perf_counter()
    _, tok_final = run_once(cache, token, pos, n_emitted)
    jax.block_until_ready(tok_final)
    dt = time.perf_counter() - t0
    tps = batch * n_tokens / dt
    return {"k": k_steps, "tok_per_s": tps,
            "dispatches": n_tokens // k_steps}


def run() -> Dict:
    rows = []
    for policy in ["dense", "quest", "raas"]:
        for n in DECODE_LENS:
            r = _bench_step(policy, n)
            name = f"fig7/{policy}-ctx{n}"
            print(f"{name},{r['us_per_step']:.0f},"
                  f"kv_mb={r['kv_bytes']/1e6:.2f}", flush=True)
            rows.append({"policy": policy, "ctx": n, **r})
    # the paper's claims, asserted:
    raas_mem = [r["kv_bytes"] for r in rows if r["policy"] == "raas"]
    dense_mem = [r["kv_bytes"] for r in rows if r["policy"] == "dense"]
    assert raas_mem[-1] == raas_mem[2], "RaaS memory must plateau"
    assert dense_mem[-1] > 4 * dense_mem[0], "Dense memory must grow"
    # dispatch-overhead sweep: tokens/sec vs chunk length
    chunk_rows = []
    for k in CHUNK_KS:
        r = _bench_chunked(k)
        print(f"fig7/chunked-K{k},tok_per_s={r['tok_per_s']:.1f},"
              f"dispatches={r['dispatches']}", flush=True)
        chunk_rows.append(r)
    base = chunk_rows[0]["tok_per_s"]
    for r in chunk_rows[1:]:
        print(f"fig7/chunked-K{r['k']}-speedup,"
              f"{r['tok_per_s']/base:.2f}x", flush=True)
    return {"rows": rows, "chunked": chunk_rows}


if __name__ == "__main__":
    run()
