"""Paper Fig. 7 proxy: per-step latency and KV memory vs decode length.

Claims reproduced:
  * Dense decode step cost grows with N (O(N) per step, O(N^2) total);
    RaaS/Quest per-step cost is O(L), flat in N.
  * Dense and Quest KV memory grow linearly with N; RaaS plateaus at
    the budget L.

Latency here is measured wall-clock on CPU for the *attention step*
shapes at growing cache sizes; memory is the exact static allocation
of each policy's cache (which is the paper's point — it is static).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_MODEL, policy_cfg
from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core import policies
from repro.core.attention import decode_attend

DECODE_LENS = [256, 512, 1024, 2048, 4096, 8192]
BUDGET = 512


def _bench_step(policy: str, n_ctx: int, iters: int = 20) -> Dict:
    cfg = BENCH_MODEL
    raas = policy_cfg(policy, BUDGET, page_size=16)
    n_slots = policies.cache_slots(raas, n_ctx + iters + 1, 64)
    spec = pc.CacheSpec(n_slots, raas.page_size, cfg.n_kv_heads,
                        cfg.resolved_head_dim, jnp.float32)
    cache = pc.init_cache(spec, 1)
    rng = np.random.default_rng(0)
    KV, hd, H = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_heads
    # simulate a cache that has already absorbed n_ctx decode tokens
    k = jnp.asarray(rng.standard_normal((1, min(n_ctx, 64), KV, hd)),
                    jnp.float32)
    cache = pc.ingest_prefill(cache, k, k,
                              jnp.asarray([min(n_ctx, 64)]))
    step = jax.jit(lambda c, q, kn, vn: decode_attend(c, q, kn, vn, raas))
    q = jnp.asarray(rng.standard_normal((1, H, hd)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((1, KV, hd)), jnp.float32)
    # fill to n_ctx
    for _ in range(min(n_ctx, n_slots * raas.page_size // 2)):
        cache, _, _ = step(cache, q, kn, kn)
    jax.block_until_ready(cache.k_pages)
    t0 = time.perf_counter()
    for _ in range(iters):
        cache, ctx, _ = step(cache, q, kn, kn)
    jax.block_until_ready(ctx)
    us = (time.perf_counter() - t0) / iters * 1e6
    kv_bytes = cache.k_pages.nbytes + cache.v_pages.nbytes
    return {"us_per_step": us, "kv_bytes": kv_bytes}


def run() -> Dict:
    rows = []
    for policy in ["dense", "quest", "raas"]:
        for n in DECODE_LENS:
            r = _bench_step(policy, n)
            name = f"fig7/{policy}-ctx{n}"
            print(f"{name},{r['us_per_step']:.0f},"
                  f"kv_mb={r['kv_bytes']/1e6:.2f}", flush=True)
            rows.append({"policy": policy, "ctx": n, **r})
    # the paper's claims, asserted:
    raas_mem = [r["kv_bytes"] for r in rows if r["policy"] == "raas"]
    dense_mem = [r["kv_bytes"] for r in rows if r["policy"] == "dense"]
    assert raas_mem[-1] == raas_mem[2], "RaaS memory must plateau"
    assert dense_mem[-1] > 4 * dense_mem[0], "Dense memory must grow"
    return {"rows": rows}


if __name__ == "__main__":
    run()
