"""Paper Fig. 7 proxy: per-step latency, attention traffic and KV
memory vs decode length, plus the serving-stack dispatch-overhead
sweep.  Emits a machine-readable ``BENCH_fig7.json`` at the repo root
so the perf trajectory is tracked across PRs.

Claims reproduced:
  * Dense decode step cost grows with N (O(N) per step, O(N^2) total);
    RaaS/Quest per-step cost is O(L), flat in N.
  * Dense and Quest KV memory grow linearly with N; RaaS plateaus at
    the budget L.
  * Zero-copy kernel traffic: the index-mapped paged kernel streams
    exactly the selected page table — ``attn_bytes_kernel`` (the
    kernel's analytic HBM traffic, exact by construction from its
    grid x BlockSpecs) is flat in N for RaaS and Quest at fixed budget
    L and grows linearly for dense.
  * Fused multi-token decode: one jitted dispatch per K tokens —
    tokens/sec at K=1 vs K=8/16/32 quantifies the per-token dispatch +
    host-round-trip overhead the chunked engine removes (jnp backend).
  * Zero-copy paged *prefill*: analytic attention traffic of chunked
    long-prompt ingest (``ops.flash_prefill_cost`` — exact from the
    kernel grid x each chunk's resume table, with the engine's
    power-of-two ``ctx_pages`` buckets) for the in-place paged kernel
    vs the old token-major gather path, per prompt length.

Wall-clock is measured on CPU for the *attention step* at growing
cache sizes, on both the jnp oracle and the Pallas interpret backend;
memory is the exact static allocation of each policy's cache — every
array of it, including rep keys and page metadata (which is the
paper's point — it is static).  ``cost_bytes_step_jnp`` is XLA's
HloCostAnalysis "bytes accessed" for the whole jitted decode step on
the jnp backend; note XLA charges a gather its full operand, so this
column overstates O(N)-slot policies (quest) — the kernel-native
column is the honest traffic number, and for RaaS (fixed O(L) shapes)
the two agree on flatness.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_MODEL, policy_cfg
from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core.attention import decode_attend
from repro.core.policy_base import get_policy
from repro.kernels import ops
from repro.models import model as M

DECODE_LENS = [256, 512, 1024, 2048, 4096, 8192]
BUDGET = 512
CHUNK_KS = [1, 8, 16, 32]
PREFILL_LENS = [256, 512, 1024, 2048, 4096]
OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig7.json"


def _prefill_traffic_rows(page_size: int = 16, chunk: int = 64):
    """Analytic per-prompt-token attention traffic of chunked ingest at
    growing prompt lengths: the zero-copy paged kernel vs the
    token-major gather path.  Deterministic — no wall-clock, exactly
    the accounting the serving engine performs per dispatch: the
    buckets come from ``engine.prefill_ctx_pages`` (the engine's own
    bucketing policy, imported so these rows cannot drift from it) and
    the geometry from ``ops.paged_prefill_geometry``."""
    from repro.serving.engine import prefill_ctx_pages

    cfg = BENCH_MODEL
    rows = []
    for N in PREFILL_LENS:
        prefill_pages = -(-N // page_size)
        paged = gather = 0
        pos = 0
        while pos < N:
            n = min(chunk, N - pos)
            ctx_pages = prefill_ctx_pages(pos + n, page_size,
                                          prefill_pages)
            bQ, ppb = ops.paged_prefill_geometry(chunk, ctx_pages,
                                                 page_size)
            c = ops.flash_prefill_cost(
                H=cfg.n_heads, KV=cfg.n_kv_heads,
                hd=cfg.resolved_head_dim, Sq=chunk,
                ctx_tokens=ctx_pages * page_size,
                q_offset=pos, kv_len=pos + n,
                block_q=bQ, block_kv=ppb * page_size)
            paged += c["bytes_accessed"]
            gather += c["bytes_accessed"] + c["gather_bytes"]
            pos += n
        rows.append({"prompt_len": N,
                     "prefill_bytes_per_token_paged": paged / N,
                     "prefill_bytes_per_token_gather": gather / N})
    return rows


def _bench_step(policy: str, n_ctx: int, iters: int = 20,
                iters_interpret: int = 3) -> Dict:
    cfg = BENCH_MODEL
    raas = policy_cfg(policy, BUDGET, page_size=16)
    pol = get_policy(policy)
    n_slots = pol.cache_slots(raas, n_ctx + iters + 1, 64)
    spec = pc.CacheSpec(n_slots, raas.page_size, cfg.n_kv_heads,
                        cfg.resolved_head_dim, jnp.float32)
    cache = pc.init_cache(spec, 1)
    rng = np.random.default_rng(0)
    KV, hd, H = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_heads
    # simulate a cache that has already absorbed n_ctx decode tokens
    k = jnp.asarray(rng.standard_normal((1, min(n_ctx, 64), KV, hd)),
                    jnp.float32)
    cache = pc.ingest_prefill(cache, k, k,
                              jnp.asarray([min(n_ctx, 64)]))
    q = jnp.asarray(rng.standard_normal((1, H, hd)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((1, KV, hd)), jnp.float32)
    # AOT-compile once per (policy, ctx): the same executable serves
    # the fill loop, the timing loop, and cost_analysis.
    step_c = jax.jit(
        lambda c, q, kn, vn: decode_attend(c, q, kn, vn, raas)) \
        .lower(cache, q, kn, kn).compile()
    # fill to n_ctx
    for _ in range(min(n_ctx, n_slots * raas.page_size // 2)):
        cache, _, _ = step_c(cache, q, kn, kn)
    jax.block_until_ready(cache.k_pages)

    def timed(fn, cache, iters):
        c = cache
        c, ctx, _ = fn(c, q, kn, kn)          # warm up
        jax.block_until_ready(ctx)
        t0 = time.perf_counter()
        for _ in range(iters):
            c, ctx, _ = fn(c, q, kn, kn)
        jax.block_until_ready(ctx)
        return (time.perf_counter() - t0) / iters * 1e6

    us_jnp = timed(step_c, cache, iters)
    step_interp = jax.jit(lambda c, q, kn, vn: decode_attend(
        c, q, kn, vn, raas, impl="pallas_interpret"))
    us_interp = timed(step_interp, cache, iters_interpret)

    # the selection table the kernel would stream (policy-agnostic:
    # ask the policy itself against the real scores)
    scale = 1.0 / hd ** 0.5
    scores = ops.page_score(q, cache.rep_min, cache.rep_max,
                            cache.valid_pages(), scale)
    sel = pol.select_pages(cache, scores, raas)
    n_sel = n_slots if sel is None else int(sel.shape[1])
    kcost = ops.paged_decode_attention_cost(
        B=1, KV=KV, G=H // KV, hd=hd, P=raas.page_size, n_sel=n_sel)

    cost = step_c.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]

    # full footprint: K/V pages + rep keys + per-page metadata
    kv_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
    return {"us_per_step_jnp": us_jnp,
            "us_per_step_pallas_interpret": us_interp,
            "kv_bytes": kv_bytes,
            "n_sel_pages": n_sel,
            "attn_bytes_kernel": kcost["bytes_accessed"],
            "attn_flops_kernel": kcost["flops"],
            "cost_bytes_step_jnp": float(cost.get("bytes accessed", -1.0))}


def _bench_chunked(k_steps: int, n_tokens: int = 128,
                   batch: int = 4) -> Dict:
    """End-to-end decode throughput of the fused ``decode_chunk`` at
    chunk length K: the K=1 row is the old one-dispatch-per-token
    engine loop (host argmax round-trip per token); larger K amortises
    dispatch + sync across the chunk."""
    cfg = BENCH_MODEL
    raas = policy_cfg("raas", BUDGET, page_size=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_seq = 64 + n_tokens + k_steps + 1
    cache = M.init_model_cache(cfg, raas, batch, max_seq, prefill_len=64)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 64)),
                       jnp.int32)
    cache, logits = jax.jit(
        lambda p, c, t, l: M.prefill(p, cfg, t, l, c))(
            params, cache, toks, jnp.full((batch,), 64, jnp.int32))

    chunk = jax.jit(
        lambda p, c, tok, pos, act, n, eos, mx: M.decode_chunk(
            p, cfg, c, tok, pos, act, n, eos, mx, raas,
            steps=k_steps, max_seq=max_seq),
        static_argnames=())
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.full((batch,), 64, jnp.int32)
    active = jnp.ones((batch,), bool)
    n_emitted = jnp.ones((batch,), jnp.int32)
    eos = jnp.full((batch,), -1, jnp.int32)
    mx = jnp.full((batch,), n_tokens + k_steps + 1, jnp.int32)

    def run_once(cache, token, pos, n_emitted):
        for _ in range(n_tokens // k_steps):
            cache, out = chunk(params, cache, token, pos, active,
                               n_emitted, eos, mx)
            # chunk boundary: the engine syncs here
            token = out.token
            pos, n_emitted = out.pos, out.n_emitted
            np.asarray(token)
        return cache, token

    run_once(cache, token, pos, n_emitted)          # compile
    t0 = time.perf_counter()
    _, tok_final = run_once(cache, token, pos, n_emitted)
    jax.block_until_ready(tok_final)
    dt = time.perf_counter() - t0
    tps = batch * n_tokens / dt
    return {"k": k_steps, "tok_per_s": tps,
            "dispatches": n_tokens // k_steps}


def _assert_claims(rows) -> None:
    by = lambda p: [r for r in rows if r["policy"] == p]
    raas, quest, dense = by("raas"), by("quest"), by("dense")
    # memory: RaaS plateaus, dense grows
    assert raas[-1]["kv_bytes"] == raas[2]["kv_bytes"], \
        "RaaS memory must plateau"
    assert dense[-1]["kv_bytes"] > 4 * dense[0]["kv_bytes"], \
        "Dense memory must grow"
    # zero-copy kernel traffic: flat in N for the O(L)-time policies
    # (once N exceeds the budget L — below it the table is smaller)...
    for name, rs in (("raas", raas), ("quest", quest)):
        vals = [r["attn_bytes_kernel"] for r in rs if r["ctx"] >= BUDGET]
        assert max(vals) <= 1.05 * min(vals), \
            f"{name} kernel attention bytes must be flat in N: {vals}"
    # ... and O(N) for dense
    assert dense[-1]["attn_bytes_kernel"] > 4 * dense[0]["attn_bytes_kernel"]
    # RaaS runs on O(L)-pinned shapes: the whole jitted step's cost-model
    # traffic is exactly constant in N
    vals = [r["cost_bytes_step_jnp"] for r in raas]
    assert max(vals) <= 1.01 * min(vals), \
        f"raas step bytes must be flat in N: {vals}"
    # wall-clock (CPU; generous margins — deterministic claims live in
    # the bytes columns above): RaaS shapes are pinned at the budget so
    # its step time is flat on both backends; Quest attends O(L) but
    # pays an O(N) rep scan + top-k, so it must stay well below dense
    # at the longest decode even if not perfectly flat.
    for col in ("us_per_step_jnp", "us_per_step_pallas_interpret"):
        vals = [r[col] for r in raas]
        assert vals[-1] <= 5.0 * min(vals), \
            f"raas {col} should be flat in N: {vals}"
    assert quest[-1]["us_per_step_jnp"] < dense[-1]["us_per_step_jnp"], \
        "quest per-step latency must beat dense at the longest decode"


def run() -> Dict:
    rows = []
    for policy in ["dense", "quest", "raas"]:
        for n in DECODE_LENS:
            r = _bench_step(policy, n)
            name = f"fig7/{policy}-ctx{n}"
            print(f"{name},{r['us_per_step_jnp']:.0f}us,"
                  f"interp={r['us_per_step_pallas_interpret']:.0f}us,"
                  f"kv_mb={r['kv_bytes']/1e6:.2f},"
                  f"attn_kb={r['attn_bytes_kernel']/1e3:.1f}", flush=True)
            rows.append({"policy": policy, "ctx": n, **r})
    _assert_claims(rows)
    # dispatch-overhead sweep: tokens/sec vs chunk length
    chunk_rows = []
    for k in CHUNK_KS:
        r = _bench_chunked(k)
        print(f"fig7/chunked-K{k},tok_per_s={r['tok_per_s']:.1f},"
              f"dispatches={r['dispatches']}", flush=True)
        chunk_rows.append(r)
    base = chunk_rows[0]["tok_per_s"]
    for r in chunk_rows[1:]:
        print(f"fig7/chunked-K{r['k']}-speedup,"
              f"{r['tok_per_s']/base:.2f}x", flush=True)
    prefill_rows = _prefill_traffic_rows()
    for r in prefill_rows:
        print(f"fig7/prefill-N{r['prompt_len']},"
              f"paged={r['prefill_bytes_per_token_paged']:.0f}B/tok,"
              f"gather={r['prefill_bytes_per_token_gather']:.0f}B/tok",
              flush=True)
        # the zero-copy claim holds at every prompt length
        assert r["prefill_bytes_per_token_paged"] \
            < r["prefill_bytes_per_token_gather"], r
    result = {"schema": "fig7/v3-paged-prefill",
              "budget_tokens": BUDGET,
              "decode_lens": DECODE_LENS,
              "rows": rows, "chunked": chunk_rows,
              "prefill_traffic": prefill_rows}
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print(f"fig7: wrote {OUT_PATH}", flush=True)
    return result


if __name__ == "__main__":
    run()
