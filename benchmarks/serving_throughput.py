"""Serving-throughput sweep: chunked-prefill continuous batching vs the
sequential one-request-at-a-time baseline, over mixed prompt/output
lengths.  Emits ``BENCH_serving.json`` at the repo root.

What is measured (and why it is honest):
  * **tokens/sec from true emitted counts** — ``Engine.tokens_emitted``
    comes from the device-side ``emitted`` mask, so chunks whose lanes
    finish mid-chunk contribute only the tokens actually produced (the
    old engine multiplied dispatches by the chunk length).
  * **dispatch counts** — the continuous-batching loop interleaves
    batched prefill chunks with fused decode chunks, so admission
    overlaps active decode; the sequential baseline pays one prefill +
    a full decode run per request with a single lane busy.  The sweep
    asserts ``dispatches_continuous < dispatches_sequential`` — the
    structural form of the overlap claim (same work, fewer, fuller
    dispatches).
  * **output invariance** — continuous batching must not change any
    request's tokens: outputs are compared against the sequential run
    byte-for-byte.

Workload: prompts spanning well below to several times the per-dispatch
``prefill_chunk`` (long prompts genuinely exercise multi-chunk ingest)
crossed with short and long decode budgets.

A separate **prefill-heavy row** (long prompts, outputs of a token or
two — the RPC-style re-ingest regime where prefill dominates) reports
``prefill_bytes_per_token``: the engine's analytic per-prompt-token
attention traffic (``ops.flash_prefill_cost`` — exact from the kernel
grid and each dispatch's chunk-resume table), for the zero-copy paged
kernel actually used and for the pre-paged token-major gather path.
The row asserts the paged path strictly beats the gather path, and
that the power-of-two ``ctx_pages`` bucketing held prefill
compilations at O(log prefill_pages).

A **prefix-cache row** (schema ``serving/v5-prefix-cache``) serves a
fleet sharing one long prompt prefix twice — prefix caching on and
off — and asserts the cached run's outputs are byte-identical while
its ``prefill_tokens`` collapse by exactly ``prefix_cached_tokens``
(only the unshared suffixes, plus the first fleet member's full
prompt, ever run through ``prefill_chunk``).

A **preemption row** (schema ``serving/v6-preemption``) pins every
lane with a long decode while short requests queue, forcing the
scheduler's graceful-degradation path (``preempt_after=1``): long
decodes are checkpointed to host, their lanes recycled for the queue,
and restored when pressure clears.  The row asserts the preempted
fleet's outputs are byte-identical to the uninterrupted run, that
checkpoints/restores really fired, and reports warm
``checkpoint_lane`` / ``restore_lane`` wall-clock (the cost of one
lane's device->host round trip).

``--mesh data=N`` adds a **sharded row**: the same workload through a
lane-sharded engine under an N-device mesh (forced host devices on
CPU).  The row asserts the sharded engine's outputs are byte-identical
to the single-device continuous run and records per-device paged-cache
bytes (from addressable-shard shapes — the O(L*B/n_dev) claim).  The
sharded pass also re-runs the shared-prefix fleet under the mesh and
asserts the same outputs and the same cached-token count.

Forcing host devices splits the CPU, which skews the *baseline* rows'
wall-clock — so when a sharded run finds an existing artifact for the
same schema and workload, it keeps that artifact's continuous /
sequential timings (measured in a normal single-device process) and
only adds its own sharded row.  Regenerate in two passes::

    PYTHONPATH=src:. python benchmarks/serving_throughput.py
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src:. python benchmarks/serving_throughput.py --mesh data=4
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks.common import BENCH_MODEL, policy_cfg
from repro.config import ServeConfig
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

BATCH_SLOTS = 4
MAX_PREFILL = 128
PREFILL_CHUNK = 32
CHUNK_STEPS = 8
BUDGET = 256
PAGE_SIZE = 16


def _workload(n_requests: int, rng) -> List[Request]:
    prompt_lens = [8, 24, 48, 96, 128]         # 0.25x .. 4x prefill_chunk
    out_lens = [8, 24, 48]
    reqs = []
    for i in range(n_requests):
        plen = prompt_lens[i % len(prompt_lens)]
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, BENCH_MODEL.vocab_size,
                                size=plen).astype(np.int32),
            max_new_tokens=out_lens[(i // len(prompt_lens)) % len(out_lens)]))
    return reqs


def _workload_prefill_heavy(n_requests: int, rng) -> List[Request]:
    """Long prompts, short outputs: prefill dominates end-to-end."""
    prompt_lens = [96, 128, 64, 112, 80]       # 2x .. 4x prefill_chunk
    out_lens = [1, 2, 3]
    return [Request(
        uid=i,
        prompt=rng.integers(0, BENCH_MODEL.vocab_size,
                            size=prompt_lens[i % len(prompt_lens)])
        .astype(np.int32),
        max_new_tokens=out_lens[i % len(out_lens)])
        for i in range(n_requests)]


def _workload_shared_prefix(n_requests: int, rng,
                            prefix_len: int = 96,
                            suffix_len: int = 16) -> List[Request]:
    """A fleet sharing one long prompt prefix (system-prompt regime):
    each request appends a distinct suffix, so with prefix caching only
    the suffixes (plus the first fleet member's full prompt) ever run
    through ``prefill_chunk``."""
    prefix = rng.integers(0, BENCH_MODEL.vocab_size,
                          size=prefix_len).astype(np.int32)
    return [Request(
        uid=i,
        prompt=np.concatenate(
            [prefix, rng.integers(0, BENCH_MODEL.vocab_size,
                                  size=suffix_len).astype(np.int32)]),
        max_new_tokens=8)
        for i in range(n_requests)]


def _workload_preempt(rng) -> List[Request]:
    """Every lane pinned by a long decode while short requests queue:
    guaranteed admission starvation, so ``preempt_after=1`` must drive
    the checkpoint/restore degradation path."""
    longs = [Request(
        uid=i, prompt=rng.integers(0, BENCH_MODEL.vocab_size,
                                   size=16).astype(np.int32),
        max_new_tokens=48) for i in range(BATCH_SLOTS)]
    shorts = [Request(
        uid=BATCH_SLOTS + i,
        prompt=rng.integers(0, BENCH_MODEL.vocab_size,
                            size=16).astype(np.int32),
        max_new_tokens=8) for i in range(2)]
    return longs + shorts


def _engine(params, max_seq: int, mesh=None,
            prefix_caching: bool = True) -> Engine:
    raas = policy_cfg("raas", BUDGET, page_size=PAGE_SIZE)
    cfg = ServeConfig(batch_slots=BATCH_SLOTS, max_seq=max_seq,
                      max_prefill=MAX_PREFILL,
                      prefill_chunk=PREFILL_CHUNK,
                      chunk_steps=CHUNK_STEPS,
                      prefix_caching=prefix_caching)
    return Engine(params, BENCH_MODEL, raas, cfg, mesh=mesh)


def _run_continuous(params, reqs, max_seq, mesh=None,
                    prefix_caching: bool = True) -> Dict:
    eng = _engine(params, max_seq, mesh=mesh,
                  prefix_caching=prefix_caching)
    t0 = time.perf_counter()
    done = serve(eng, reqs)
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs)
    return {
        "wall_s": wall,
        "tokens_emitted": eng.tokens_emitted,
        "prefill_tokens": eng.prefill_tokens,
        "decode_dispatches": eng.dispatches,
        "prefill_dispatches": eng.prefill_dispatches,
        "dispatches": eng.dispatches + eng.prefill_dispatches,
        "steps_executed": eng.steps_executed,
        "tok_per_s": eng.tokens_emitted / max(wall, 1e-9),
        "kv_bytes_global": eng.kv_cache_bytes(),
        "kv_bytes_per_device": eng.kv_cache_bytes_per_device(),
        "prefill_traces": eng.prefill_traces,
        "prefill_kv_bytes": eng.prefill_kv_bytes,
        "prefill_kv_bytes_gather": eng.prefill_kv_bytes_gather,
        "prefill_bytes_per_token":
            eng.prefill_kv_bytes / max(eng.prefill_tokens, 1),
        "prefill_bytes_per_token_gather":
            eng.prefill_kv_bytes_gather / max(eng.prefill_tokens, 1),
        "prefix_caching": eng.prefix_caching,
        "prefix_cached_tokens": eng.prefix_cached_tokens,
        "prefix_mounts": eng.prefix_mounts,
        "prefix_clones": eng.prefix_clones,
        "session_hits": eng.session_hits,
        "pool_dispatches": eng.pool_dispatches,
        "outputs": {r.uid: list(r.output) for r in done},
    }


def _run_sharded(params, reqs, max_seq, mesh_spec: str) -> Dict:
    """Continuous batching through the lane-sharded engine.  Builds the
    mesh from ``mesh_spec`` (raises with an XLA_FLAGS hint when the
    process lacks devices)."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_serving_mesh(mesh_spec)
    out = _run_continuous(params, reqs, max_seq, mesh=mesh)
    out["mesh"] = mesh_spec
    out["n_devices"] = int(mesh.size)
    out["n_data"] = int(mesh.shape["data"])
    return out


def _donation_audit(params, max_seq) -> Dict:
    """Compile the bench engine's three jitted dispatches and measure
    cache donation with the repro.analysis passes: the audit must find
    zero large un-donated buffers, and on every dispatch the aliased
    (donated) bytes must cover at least the paged KV cache — the
    structural form of "donation removed the cache's second live copy".
    The KV-copy pass is skipped here: the bench runs the RaaS policy,
    whose O(L) cache is smaller than one chunk's attention intermediates
    (the quest row of `python -m repro.analysis.run` carries that
    regression)."""
    from repro.analysis import engine_audit
    eng = _engine(params, max_seq)
    findings, report = engine_audit.audit_engine(
        eng, kv_copy_min_elems={"prefill_chunk": 0, "decode_chunk": 0})
    assert not findings, "\n".join(f.format() for f in findings)
    kv_bytes = eng.kv_cache_bytes()
    for name, rep in report.items():
        assert rep["alias_bytes"] >= kv_bytes, (name, rep)
    return {
        "kv_cache_bytes": kv_bytes,
        "per_dispatch": report,
        "peak_live_bytes":
            max(r["peak_live_bytes"] for r in report.values()),
        "peak_live_bytes_undonated":
            max(r["peak_live_bytes_undonated"] for r in report.values()),
        "donation_saved_bytes":
            min(r["alias_bytes"] for r in report.values()),
    }


def _run_preemption(params, max_seq) -> Dict:
    """The graceful-degradation row: serve the starvation workload
    with ``preempt_after=1`` and assert byte parity against the same
    fleet served without preemption, then microbench one warm
    checkpoint/restore cycle."""
    import copy
    reqs = _workload_preempt(np.random.default_rng(3))
    base_eng = _engine(params, max_seq)
    base = serve(base_eng, copy.deepcopy(reqs))

    eng = _engine(params, max_seq)
    t0 = time.perf_counter()
    done = serve(eng, copy.deepcopy(reqs), preempt_after=1)
    wall = time.perf_counter() - t0
    assert eng.checkpoints >= 1 and eng.restores >= 1, \
        (eng.checkpoints, eng.restores)
    n_ck, n_rs = eng.checkpoints, eng.restores   # serve-phase counts
    outs = {r.uid: list(r.output) for r in done}
    assert outs == {r.uid: list(r.output) for r in base}, \
        "preemption changed output bytes"
    statuses = {}
    for r in done:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    assert set(statuses) <= {"OK", "PREEMPTED_RESUMED"}, statuses
    tokens = eng.tokens_emitted          # before the microbench request

    # microbench: warm per-lane checkpoint + restore (second cycle —
    # the first compiles the snapshot/restore dispatches)
    mb = Request(uid=9_999,
                 prompt=np.random.default_rng(4).integers(
                     0, BENCH_MODEL.vocab_size, size=16).astype(np.int32),
                 max_new_tokens=64)
    eng.admit(mb)
    eng.drain_prefill()
    eng.step_chunk()
    slot = eng.slot_req.index(mb)
    ck_s = rs_s = 0.0
    for _ in range(2):
        t1 = time.perf_counter()
        ck = eng.checkpoint_lane(slot)
        ck_s = time.perf_counter() - t1
        t1 = time.perf_counter()
        slot = eng.restore_lane(ck)
        jax.block_until_ready(jax.tree.leaves(eng.cache))
        rs_s = time.perf_counter() - t1
    while eng.has_active():
        eng.step_chunk()
    eng.audit_refcounts()
    return {
        "wall_s": wall,
        "tokens_emitted": tokens,
        "checkpoints": n_ck,
        "restores": n_rs,
        "statuses": statuses,
        "checkpoint_s": ck_s,
        "restore_s": rs_s,
        "workload": [{"uid": r.uid, "prompt_len": int(len(r.prompt)),
                      "max_new_tokens": r.max_new_tokens} for r in reqs],
        "outputs": outs,
    }


def _run_sequential(params, reqs, max_seq) -> Dict:
    """One request at a time: admit -> full prefill -> decode to
    completion.  Same engine geometry, one lane ever busy."""
    eng = _engine(params, max_seq)
    t0 = time.perf_counter()
    outputs = {}
    for req in reqs:
        eng.admit(req)
        finished = eng.drain_prefill()
        while eng.has_active():
            finished += eng.step_chunk()
        outputs[req.uid] = list(req.output)
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "tokens_emitted": eng.tokens_emitted,
        "decode_dispatches": eng.dispatches,
        "prefill_dispatches": eng.prefill_dispatches,
        "dispatches": eng.dispatches + eng.prefill_dispatches,
        "tok_per_s": eng.tokens_emitted / max(wall, 1e-9),
        "outputs": outputs,
    }


def run(n_requests: int = 15, write_json: bool = True,
        mesh_spec: Optional[str] = None) -> Dict:
    params = M.init_params(jax.random.PRNGKey(0), BENCH_MODEL)
    rng = np.random.default_rng(0)
    reqs = _workload(n_requests, rng)
    max_seq = MAX_PREFILL + max(r.max_new_tokens for r in reqs) + CHUNK_STEPS

    import copy
    cont = _run_continuous(params, copy.deepcopy(reqs), max_seq)
    seq = _run_sequential(params, copy.deepcopy(reqs), max_seq)

    # prefill-heavy row: the zero-copy claim, in bytes per prompt token
    ph_reqs = _workload_prefill_heavy(max(n_requests // 2, 3),
                                      np.random.default_rng(1))
    ph = _run_continuous(params, ph_reqs, max_seq)
    ph["workload"] = [{"uid": r.uid, "prompt_len": int(len(r.prompt)),
                       "max_new_tokens": r.max_new_tokens}
                      for r in ph_reqs]
    # the paged in-place path must strictly beat the token-major gather
    # path on analytic attention bytes — the whole point of the kernel
    assert 0 < ph["prefill_kv_bytes"] < ph["prefill_kv_bytes_gather"], ph
    assert cont["prefill_kv_bytes"] < cont["prefill_kv_bytes_gather"]
    # power-of-two ctx_pages bucketing: a whole multi-prompt sweep
    # compiles O(log prefill_pages) prefill variants, not O(chunks)
    max_buckets = (MAX_PREFILL // PAGE_SIZE).bit_length() + 1
    assert ph["prefill_traces"] <= max_buckets, \
        (ph["prefill_traces"], max_buckets)

    # shared-prefix row: the system-prompt fleet.  Prefix caching must
    # collapse prefill to the unshared suffixes without changing one
    # output token vs an engine with caching off.
    # fleet must outnumber the lanes: members admitted after the first
    # wave registers its prefill pages are the ones that hit the index
    sp_reqs = _workload_shared_prefix(max(n_requests, 2 * BATCH_SLOTS),
                                      np.random.default_rng(2))
    sp = _run_continuous(params, copy.deepcopy(sp_reqs), max_seq)
    sp_base = _run_continuous(params, copy.deepcopy(sp_reqs), max_seq,
                              prefix_caching=False)
    sp["workload"] = [{"uid": r.uid, "prompt_len": int(len(r.prompt)),
                       "max_new_tokens": r.max_new_tokens}
                      for r in sp_reqs]
    assert sp["outputs"] == sp_base["outputs"], \
        "prefix caching altered request outputs"
    assert sp["prefix_mounts"] + sp["prefix_clones"] >= 1, sp
    assert sp["prefix_cached_tokens"] > 0
    # the collapse is exact: every cached token is a prefill token the
    # baseline paid for and this run did not
    assert sp["prefill_tokens"] \
        == sp_base["prefill_tokens"] - sp["prefix_cached_tokens"], \
        (sp["prefill_tokens"], sp_base["prefill_tokens"],
         sp["prefix_cached_tokens"])
    sp["prefill_tokens_uncached"] = sp_base["prefill_tokens"]
    sp["prefill_collapse"] = \
        1 - sp["prefill_tokens"] / sp_base["prefill_tokens"]

    # preemption row: graceful degradation under page-pool pressure,
    # byte parity asserted inside against the uninterrupted fleet
    pre = _run_preemption(params, max_seq)

    don = _donation_audit(params, max_seq)

    shard = None
    shard_sp = None
    if mesh_spec:
        shard = _run_sharded(params, copy.deepcopy(reqs), max_seq, mesh_spec)
        # sharding the lane axis must not change a single output token
        assert shard["outputs"] == cont["outputs"], \
            "sharded engine altered request outputs"
        assert shard["tokens_emitted"] == cont["tokens_emitted"]
        assert shard["dispatches"] == cont["dispatches"]
        # same schedule -> same chunk-resume tables -> identical
        # analytic prefill traffic under the mesh
        assert shard["prefill_kv_bytes"] == cont["prefill_kv_bytes"]
        # the O(L*B/n_dev) claim: per-device paged-cache bytes shrink by
        # exactly the data-axis size (lane axis shards evenly)
        assert shard["kv_bytes_per_device"] * shard["n_data"] \
            == shard["kv_bytes_global"] == cont["kv_bytes_global"], shard
        # prefix caching under the mesh: same mounts/clones, same cached
        # tokens, byte-identical outputs to the single-device run
        shard_sp = _run_sharded(params, copy.deepcopy(sp_reqs), max_seq,
                                mesh_spec)
        assert shard_sp["outputs"] == sp["outputs"], \
            "sharded prefix caching altered request outputs"
        assert shard_sp["prefix_cached_tokens"] \
            == sp["prefix_cached_tokens"], (shard_sp, sp)
        assert shard_sp["prefill_tokens"] == sp["prefill_tokens"]

    # continuous batching must not change a single output token
    assert cont["outputs"] == seq["outputs"], \
        "continuous batching altered request outputs"
    # true counts: every emitted token is accounted, none invented
    total_out = sum(len(v) for v in cont["outputs"].values())
    assert cont["tokens_emitted"] == total_out == seq["tokens_emitted"]
    # admission overlaps decode: the batched loop needs strictly fewer
    # dispatches than the sequential prefill+decode baseline
    assert cont["dispatches"] < seq["dispatches"], \
        (cont["dispatches"], seq["dispatches"])

    rows = [("continuous", cont), ("sequential", seq),
            ("prefill_heavy", ph), ("prefix_cache", sp)]
    print(f"serving/preemption,"
          f"checkpoints={pre['checkpoints']},restores={pre['restores']},"
          f"checkpoint_us={pre['checkpoint_s']*1e6:.0f},"
          f"restore_us={pre['restore_s']*1e6:.0f},"
          f"statuses={pre['statuses']}", flush=True)
    if shard is not None:
        rows.append((f"sharded[{shard['mesh']}]", shard))
    if shard_sp is not None:
        rows.append((f"sharded_prefix[{shard_sp['mesh']}]", shard_sp))
    for name, r in rows:
        print(f"serving/{name},{r['wall_s']*1e6:.0f}us,"
              f"tok_per_s={r['tok_per_s']:.1f},"
              f"dispatches={r['dispatches']},"
              f"tokens={r['tokens_emitted']}", flush=True)
    print(f"serving/prefill-heavy,"
          f"prefill_bytes_per_token={ph['prefill_bytes_per_token']:.0f},"
          f"gather={ph['prefill_bytes_per_token_gather']:.0f},"
          f"saved="
          f"{1 - ph['prefill_kv_bytes'] / ph['prefill_kv_bytes_gather']:.1%},"
          f"prefill_traces={ph['prefill_traces']}", flush=True)
    if shard is not None:
        print(f"serving/sharded,kv_per_device="
              f"{shard['kv_bytes_per_device']/1e6:.2f}MB,"
              f"kv_global={shard['kv_bytes_global']/1e6:.2f}MB,"
              f"n_devices={shard['n_devices']}", flush=True)
    print(f"serving/prefix-cache,"
          f"cached_tokens={sp['prefix_cached_tokens']},"
          f"prefill={sp['prefill_tokens']},"
          f"uncached_would_be={sp['prefill_tokens_uncached']},"
          f"collapse={sp['prefill_collapse']:.1%},"
          f"mounts={sp['prefix_mounts']},clones={sp['prefix_clones']}",
          flush=True)
    print(f"serving/donation,saved="
          f"{don['donation_saved_bytes']/1e6:.2f}MB,"
          f"peak_live={don['peak_live_bytes']/1e6:.2f}MB,"
          f"undonated_would_be="
          f"{don['peak_live_bytes_undonated']/1e6:.2f}MB,"
          f"kv_cache={don['kv_cache_bytes']/1e6:.2f}MB", flush=True)
    speedup = cont["tok_per_s"] / max(seq["tok_per_s"], 1e-9)
    print(f"serving/continuous-vs-sequential,{speedup:.2f}x,"
          f"dispatch_ratio="
          f"{cont['dispatches'] / max(seq['dispatches'], 1):.2f}",
          flush=True)

    result = {
        "schema": "serving/v6-preemption",
        "model": BENCH_MODEL.name,
        "batch_slots": BATCH_SLOTS,
        "max_prefill": MAX_PREFILL,
        "prefill_chunk": PREFILL_CHUNK,
        "chunk_steps": CHUNK_STEPS,
        "budget_tokens": BUDGET,
        "n_requests": n_requests,
        "workload": [{"uid": r.uid, "prompt_len": int(len(r.prompt)),
                      "max_new_tokens": r.max_new_tokens} for r in reqs],
        "continuous": {k: v for k, v in cont.items() if k != "outputs"},
        "sequential": {k: v for k, v in seq.items() if k != "outputs"},
        "prefill_heavy": {k: v for k, v in ph.items() if k != "outputs"},
        "prefix_cache": {k: v for k, v in sp.items() if k != "outputs"},
        "preemption": {k: v for k, v in pre.items() if k != "outputs"},
        "donation": don,
        "throughput_speedup": speedup,
    }
    if shard is not None:
        result["sharded"] = {k: v for k, v in shard.items()
                             if k != "outputs"}
        result["sharded"]["forced_host_devices"] = int(jax.device_count())
    if shard_sp is not None:
        result["sharded_prefix"] = {k: v for k, v in shard_sp.items()
                                    if k != "outputs"}
    if write_json:
        # two-pass artifact contract (module docstring): a sharded run
        # splits the CPU into forced host devices, skewing ITS baseline
        # wall-clock, so it keeps a matching single-device artifact's
        # baseline rows; a single-device rerun keeps a matching
        # artifact's sharded row.  Both merges (and their absence) are
        # announced — nothing is kept or dropped silently.
        prev = None
        if OUT_PATH.exists():
            try:
                prev = json.loads(OUT_PATH.read_text())
            except (OSError, json.JSONDecodeError):
                prev = None
            if prev is not None \
                    and (prev.get("schema") != result["schema"]
                         or prev.get("workload") != result["workload"]):
                prev = None
        if shard is not None:
            if prev is not None:
                for k in ("continuous", "sequential", "prefill_heavy",
                          "prefix_cache", "preemption",
                          "throughput_speedup"):
                    result[k] = prev[k]
                print("serving: kept single-device baseline rows from "
                      f"existing {OUT_PATH.name}", flush=True)
            else:
                result["baseline_env"] = (
                    f"forced_host_devices={jax.device_count()}: baseline "
                    "wall-clock is skewed by the CPU split — rerun the "
                    "single-device pass, then this sharded pass, to "
                    "restore honest baselines")
                print("serving: WARNING — no matching single-device "
                      f"artifact at {OUT_PATH.name}; baseline rows below "
                      "were measured on a CPU split into "
                      f"{jax.device_count()} host devices and their "
                      "wall-clock is NOT comparable", flush=True)
        elif prev is not None and "sharded" in prev:
            result["sharded"] = prev["sharded"]
            if "sharded_prefix" in prev:
                result["sharded_prefix"] = prev["sharded_prefix"]
            print(f"serving: kept sharded row from existing "
                  f"{OUT_PATH.name} (rerun --mesh to refresh it)",
                  flush=True)
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
        print(f"serving: wrote {OUT_PATH}", flush=True)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=15)
    ap.add_argument("--mesh", default="",
                    help="add a sharded row, e.g. 'data=4' (on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N before running)")
    a = ap.parse_args()
    run(n_requests=a.requests, mesh_spec=a.mesh or None)
