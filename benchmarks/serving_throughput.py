"""Serving-throughput sweep: chunked-prefill continuous batching vs the
sequential one-request-at-a-time baseline, over mixed prompt/output
lengths.  Emits ``BENCH_serving.json`` at the repo root.

What is measured (and why it is honest):
  * **tokens/sec from true emitted counts** — ``Engine.tokens_emitted``
    comes from the device-side ``emitted`` mask, so chunks whose lanes
    finish mid-chunk contribute only the tokens actually produced (the
    old engine multiplied dispatches by the chunk length).
  * **dispatch counts** — the continuous-batching loop interleaves
    batched prefill chunks with fused decode chunks, so admission
    overlaps active decode; the sequential baseline pays one prefill +
    a full decode run per request with a single lane busy.  The sweep
    asserts ``dispatches_continuous < dispatches_sequential`` — the
    structural form of the overlap claim (same work, fewer, fuller
    dispatches).
  * **output invariance** — continuous batching must not change any
    request's tokens: outputs are compared against the sequential run
    byte-for-byte.

Workload: prompts spanning well below to several times the per-dispatch
``prefill_chunk`` (long prompts genuinely exercise multi-chunk ingest)
crossed with short and long decode budgets.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import BENCH_MODEL, policy_cfg
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

BATCH_SLOTS = 4
MAX_PREFILL = 128
PREFILL_CHUNK = 32
CHUNK_STEPS = 8
BUDGET = 256


def _workload(n_requests: int, rng) -> List[Request]:
    prompt_lens = [8, 24, 48, 96, 128]         # 0.25x .. 4x prefill_chunk
    out_lens = [8, 24, 48]
    reqs = []
    for i in range(n_requests):
        plen = prompt_lens[i % len(prompt_lens)]
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, BENCH_MODEL.vocab_size,
                                size=plen).astype(np.int32),
            max_new_tokens=out_lens[(i // len(prompt_lens)) % len(out_lens)]))
    return reqs


def _engine(params, max_seq: int) -> Engine:
    raas = policy_cfg("raas", BUDGET, page_size=16)
    return Engine(params, BENCH_MODEL, raas, batch_slots=BATCH_SLOTS,
                  max_seq=max_seq, max_prefill=MAX_PREFILL,
                  prefill_chunk=PREFILL_CHUNK, chunk_steps=CHUNK_STEPS)


def _run_continuous(params, reqs, max_seq) -> Dict:
    eng = _engine(params, max_seq)
    t0 = time.perf_counter()
    done = serve(eng, reqs)
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs)
    return {
        "wall_s": wall,
        "tokens_emitted": eng.tokens_emitted,
        "prefill_tokens": eng.prefill_tokens,
        "decode_dispatches": eng.dispatches,
        "prefill_dispatches": eng.prefill_dispatches,
        "dispatches": eng.dispatches + eng.prefill_dispatches,
        "steps_executed": eng.steps_executed,
        "tok_per_s": eng.tokens_emitted / max(wall, 1e-9),
        "outputs": {r.uid: list(r.output) for r in done},
    }


def _run_sequential(params, reqs, max_seq) -> Dict:
    """One request at a time: admit -> full prefill -> decode to
    completion.  Same engine geometry, one lane ever busy."""
    eng = _engine(params, max_seq)
    t0 = time.perf_counter()
    outputs = {}
    for req in reqs:
        eng.admit(req)
        finished = eng.drain_prefill()
        while eng.has_active():
            finished += eng.step_chunk()
        outputs[req.uid] = list(req.output)
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "tokens_emitted": eng.tokens_emitted,
        "decode_dispatches": eng.dispatches,
        "prefill_dispatches": eng.prefill_dispatches,
        "dispatches": eng.dispatches + eng.prefill_dispatches,
        "tok_per_s": eng.tokens_emitted / max(wall, 1e-9),
        "outputs": outputs,
    }


def run(n_requests: int = 15, write_json: bool = True) -> Dict:
    params = M.init_params(jax.random.PRNGKey(0), BENCH_MODEL)
    rng = np.random.default_rng(0)
    reqs = _workload(n_requests, rng)
    max_seq = MAX_PREFILL + max(r.max_new_tokens for r in reqs) + CHUNK_STEPS

    import copy
    cont = _run_continuous(params, copy.deepcopy(reqs), max_seq)
    seq = _run_sequential(params, copy.deepcopy(reqs), max_seq)

    # continuous batching must not change a single output token
    assert cont["outputs"] == seq["outputs"], \
        "continuous batching altered request outputs"
    # true counts: every emitted token is accounted, none invented
    total_out = sum(len(v) for v in cont["outputs"].values())
    assert cont["tokens_emitted"] == total_out == seq["tokens_emitted"]
    # admission overlaps decode: the batched loop needs strictly fewer
    # dispatches than the sequential prefill+decode baseline
    assert cont["dispatches"] < seq["dispatches"], \
        (cont["dispatches"], seq["dispatches"])

    for name, r in (("continuous", cont), ("sequential", seq)):
        print(f"serving/{name},{r['wall_s']*1e6:.0f}us,"
              f"tok_per_s={r['tok_per_s']:.1f},"
              f"dispatches={r['dispatches']},"
              f"tokens={r['tokens_emitted']}", flush=True)
    speedup = cont["tok_per_s"] / max(seq["tok_per_s"], 1e-9)
    print(f"serving/continuous-vs-sequential,{speedup:.2f}x,"
          f"dispatch_ratio="
          f"{cont['dispatches'] / max(seq['dispatches'], 1):.2f}",
          flush=True)

    result = {
        "schema": "serving/v1-chunked-prefill",
        "model": BENCH_MODEL.name,
        "batch_slots": BATCH_SLOTS,
        "max_prefill": MAX_PREFILL,
        "prefill_chunk": PREFILL_CHUNK,
        "chunk_steps": CHUNK_STEPS,
        "budget_tokens": BUDGET,
        "n_requests": n_requests,
        "workload": [{"uid": r.uid, "prompt_len": int(len(r.prompt)),
                      "max_new_tokens": r.max_new_tokens} for r in reqs],
        "continuous": {k: v for k, v in cont.items() if k != "outputs"},
        "sequential": {k: v for k, v in seq.items() if k != "outputs"},
        "throughput_speedup": speedup,
    }
    if write_json:
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
        print(f"serving: wrote {OUT_PATH}", flush=True)
    return result


if __name__ == "__main__":
    run()
