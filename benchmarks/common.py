"""Shared benchmark substrate: a small trained reasoner + policy evals.

``trained_reasoner()`` trains (once, then caches to experiments/) a
small dense model on the synthetic arithmetic-CoT corpus until it can
actually solve held-out problems under dense decoding — the accuracy
benchmarks then measure how each sparsity policy degrades that ability
as the cache budget shrinks, mirroring paper Fig. 6/8/9 mechanics.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.config import ModelConfig, RaasConfig, RunConfig
from repro.data.pipeline import (DataConfig, batches, make_example,
                                 prompt_of, specials, verify_answer)
from repro.launch.train import make_train_step
from repro.models import model as M
from repro.optim import adamw

CKPT_PATH = "experiments/bench_reasoner.msgpack"

BENCH_MODEL = ModelConfig(
    name="bench-reasoner", arch_type="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=128, head_dim=32)

BENCH_DATA = DataConfig(vocab_size=128, seq_len=192, chain_steps=24,
                        modulus=97, seed=0)


def trained_reasoner(steps: int = 600,
                     force: bool = False) -> Tuple[dict, ModelConfig,
                                                   DataConfig]:
    cfg, dc = BENCH_MODEL, BENCH_DATA
    params_like = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    if os.path.exists(CKPT_PATH) and not force:
        params = ckpt.restore(CKPT_PATH, {"params": params_like})["params"]
        return params, cfg, dc
    run = RunConfig(arch="bench", lr=3e-3, total_steps=steps,
                    warmup_steps=30)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, run))
    it = batches(dc, 16)
    t0 = time.time()
    for i in range(steps):
        b = next(it)
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"]),
                               "loss_mask": jnp.asarray(b["loss_mask"])})
        if i % 100 == 0:
            print(f"  [reasoner] step {i} loss {float(m['loss']):.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    ckpt.save(CKPT_PATH, {"params": params})
    return params, cfg, dc


PROMPT_CAP = 16      # prompts are padded to this (fixed jit shapes)

_JIT_CACHE: Dict = {}


def _jitted_fns(cfg: ModelConfig, raas: RaasConfig):
    """One (prefill, decode) jit pair per (cfg, raas) — prompts are
    padded to PROMPT_CAP so shapes never vary across examples (keeps
    the XLA CPU program count bounded)."""
    key = (cfg, raas)
    if key not in _JIT_CACHE:
        pf = jax.jit(lambda p, c, t, l: M.prefill(p, cfg, t, l, c))
        dc_ = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, t, pos,
                                                         c, raas))
        _JIT_CACHE[key] = (pf, dc_)
    return _JIT_CACHE[key]


def greedy_decode_with_policy(params, cfg: ModelConfig, dc: DataConfig,
                              raas: RaasConfig, index: int,
                              max_new: int = 176,
                              ) -> Tuple[np.ndarray, int, Dict]:
    """Serve one problem under a policy.  Returns (decoded, n_steps,
    stats dict with kv bytes + tokens cached)."""
    sp = specials(dc)
    prompt, plen = prompt_of(dc, index)
    assert plen <= PROMPT_CAP
    B = 1
    max_seq = PROMPT_CAP + max_new + 1
    cache = M.init_model_cache(cfg, raas, B, max_seq_len=max_seq,
                               prefill_len=PROMPT_CAP)
    kv_bytes = sum(c.attn.k_pages.nbytes + c.attn.v_pages.nbytes
                   for c in cache.per_pos if c.attn is not None)
    padded = np.zeros(PROMPT_CAP, np.int32)
    padded[:plen] = prompt
    prefill_fn, decode_fn = _jitted_fns(cfg, raas)
    cache, logits = prefill_fn(params, cache,
                               jnp.asarray(padded[None]),
                               jnp.asarray([plen], jnp.int32))
    out: List[int] = []
    tok = int(jnp.argmax(logits[0]))
    out.append(tok)
    for t in range(plen, plen + max_new):
        if tok == sp["EOS"]:
            break
        cache, logits = decode_fn(params, cache,
                                  jnp.asarray([tok], jnp.int32),
                                  jnp.asarray([t], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    stats = {"kv_bytes": kv_bytes,
             "tokens_cached": int(cache.per_pos[0].attn.page_len.sum())
             if cache.per_pos[0].attn is not None else 0}
    return np.asarray(out), len(out), stats


def accuracy_under_policy(params, cfg, dc, raas: RaasConfig,
                          n_eval: int = 24, max_new: int = 176,
                          start_index: int = 50_000) -> float:
    """Exact-match accuracy on held-out problems under a policy."""
    correct = 0
    for i in range(n_eval):
        dec, _, _ = greedy_decode_with_policy(params, cfg, dc, raas,
                                              start_index + i, max_new)
        correct += bool(verify_answer(dc, start_index + i, dec))
    return correct / n_eval


def reset_jit() -> None:
    """Drop compiled programs between benchmark sections (the XLA CPU
    JIT accumulates dylibs per program; hundreds in one process can
    fail to materialize)."""
    _JIT_CACHE.clear()
    jax.clear_caches()


def policy_cfg(policy: str, budget: int, page_size: int = 8,
               **kw) -> RaasConfig:
    return RaasConfig(policy=policy, budget_tokens=budget,
                      page_size=page_size,
                      quest_topk_pages=max(1, budget // page_size), **kw)
