"""Policy-fidelity metric: attention-mass recall vs dense (beyond-paper).

For a teacher-forced trace of the trained reasoner, we replay the
per-step (q, k, v) stream of one attention layer through each policy's
cache and measure, at every step, how much of the *dense* attention
probability mass lands on tokens the policy still retains.  This is
the model-free quantity that explains the Fig. 6 accuracy ordering:
RaaS/Quest keep recall ~1.0 because milestone pages stay resident
exactly while they still receive mass; StreamingLLM/H2O drop milestone
tokens and their recall collapses mid-chain.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import policy_cfg, trained_reasoner
from repro.core import paged_cache as pc
from repro.core.attention import decode_attend
from repro.core.policy_base import get_policy
from repro.data.pipeline import make_example, prompt_of
from repro.models import layers, model as M

POLICIES = ["raas", "quest", "h2o", "streaming"]
BUDGET = 48
LAYER = 1          # representative mid-stack layer


def _qkv_trace(params, cfg, tokens: np.ndarray):
    """Teacher-forced q/k/v stream of one layer.  [T, H|KV, hd]."""
    toks = jnp.asarray(tokens[None])
    B, T = toks.shape
    h = M._embed(params, cfg, toks, None)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    # run the stack up to LAYER, then project qkv there
    per_pos = params["blocks"][0]
    from repro.models import blocks as BL
    for li in range(LAYER):
        bp = jax.tree.map(lambda x: x[li], per_pos)
        h, _ = BL.block_train(bp, cfg, h, positions, "attn", "dense")
    bp = jax.tree.map(lambda x: x[LAYER], per_pos)
    hn = layers.rmsnorm(bp["norm_mixer"], h, cfg.norm_eps)
    q, k, v = layers.qkv_project(bp["attn"], cfg, hn, positions)
    return (np.asarray(q[0]), np.asarray(k[0]), np.asarray(v[0]))


def _dense_probs(q_t, k_hist, scale):
    """q_t [H, hd]; k_hist [t+1, KV, hd] -> prob mass per position."""
    H, hd = q_t.shape
    KV = k_hist.shape[1]
    G = H // KV
    qg = q_t.reshape(KV, G, hd)
    logits = np.einsum("kgd,tkd->kgt", qg, k_hist) * scale
    m = logits.max(-1, keepdims=True)
    e = np.exp(logits - m)
    p = e / e.sum(-1, keepdims=True)
    return p.sum((0, 1)) / (KV * G)          # mean over heads, [t+1]


def run(n_eval: int = 4, max_steps: int = 120) -> Dict:
    params, cfg, dc = trained_reasoner()
    scale = 1.0 / cfg.resolved_head_dim ** 0.5
    rows = []
    for policy in POLICIES:
        raas = policy_cfg(policy, BUDGET)
        recalls: List[float] = []
        t0 = time.time()
        for idx in range(n_eval):
            toks, _, _ = make_example(dc, 70_000 + idx)
            _, plen = prompt_of(dc, 70_000 + idx)
            T = min(len(toks), plen + max_steps)
            q_tr, k_tr, v_tr = _qkv_trace(params, cfg, toks[:T])
            n_slots = get_policy(raas.policy).cache_slots(raas, T, plen)
            spec = pc.CacheSpec(n_slots, raas.page_size, cfg.n_kv_heads,
                                cfg.resolved_head_dim, jnp.float32)
            cache = pc.init_cache(spec, 1)
            cache = pc.ingest_prefill(
                cache, jnp.asarray(k_tr[None, :plen]),
                jnp.asarray(v_tr[None, :plen]),
                jnp.asarray([plen]))
            for t in range(plen, T):
                cache, _, _ = decode_attend(
                    cache, jnp.asarray(q_tr[None, t]),
                    jnp.asarray(k_tr[None, t]),
                    jnp.asarray(v_tr[None, t]), raas)
                # retained token positions
                pos = np.asarray(cache.page_pos[0])
                ln = np.asarray(cache.page_len[0])
                retained = np.concatenate(
                    [np.arange(p, p + l) for p, l in zip(pos, ln)
                     if l > 0]) if (ln > 0).any() else np.array([], int)
                dense_p = _dense_probs(q_tr[t], k_tr[:t + 1], scale)
                recalls.append(float(dense_p[retained[
                    retained <= t]].sum()))
        us = (time.time() - t0) / max(len(recalls), 1) * 1e6
        mean_r = float(np.mean(recalls))
        p10 = float(np.percentile(recalls, 10))
        print(f"fidelity/{policy}-{BUDGET},{us:.0f},"
              f"recall_mean={mean_r:.3f};recall_p10={p10:.3f}",
              flush=True)
        rows.append({"policy": policy, "recall_mean": mean_r,
                     "recall_p10": p10})
    return {"rows": rows}


if __name__ == "__main__":
    run()
