"""Continuous-batching + fused-chunk-decode tests.

Covers the acceptance criteria of the registry/chunk refactor:
  * ``decode_chunk(K=8)`` is token-identical to eight single steps,
  * exactly one jitted dispatch per chunk, one trace per chunk length,
  * lane re-use (admit -> finish -> re-admit) is isolated: a re-used
    lane's outputs match a fresh engine, under raas AND quest_raas,
  * ``Engine.kv_cache_bytes`` accounts for every array of the paged
    cache (asserted against jax.tree byte totals).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RaasConfig
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)


def _params():
    return M.init_params(jax.random.PRNGKey(0), TINY)


def _requests(n, rng, max_new=12, eos_id=None):
    return [Request(uid=i,
                    prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new_tokens=max_new, eos_id=eos_id)
            for i in range(n)]


# ---------------------------------------------------------------------------
# chunk == K single steps
# ---------------------------------------------------------------------------
def test_decode_chunk_k8_matches_eight_single_steps():
    params = _params()
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    rng = np.random.default_rng(0)
    prompts = _requests(2, rng, max_new=30)

    eng_a = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                   max_prefill=16)
    eng_b = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                   max_prefill=16)
    reqs_a = copy.deepcopy(prompts)
    reqs_b = copy.deepcopy(prompts)
    for r in reqs_a:
        eng_a.admit(r)
    eng_a.drain_prefill()
    for r in reqs_b:
        eng_b.admit(r)
    eng_b.drain_prefill()

    for _ in range(8):
        eng_a.step()
    eng_b.step_chunk(8)

    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.output == rb.output
    np.testing.assert_array_equal(eng_a.pos, eng_b.pos)
    np.testing.assert_array_equal(eng_a.last_token, eng_b.last_token)
    np.testing.assert_array_equal(eng_a.active, eng_b.active)
    # the fused engine paid ONE dispatch for the whole chunk
    assert eng_b.dispatches == 1
    assert eng_a.dispatches == 8


def test_chunk_one_trace_many_dispatches():
    """The chunk fn compiles once per chunk length; every later chunk
    is a cache hit — one jitted dispatch per chunk, no retraces."""
    params = _params()
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=256,
                 max_prefill=16)
    rng = np.random.default_rng(1)
    for r in _requests(2, rng, max_new=40):
        eng.admit(r)
    eng.drain_prefill()
    for _ in range(4):
        eng.step_chunk(8)
    assert eng.dispatches == 4
    assert eng.traces == 1
    assert eng.steps_executed == 32


def test_mid_chunk_finish_masks_output():
    """A request whose budget ends mid-chunk emits exactly its budget,
    even though the dispatch runs the full K steps."""
    params = _params()
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                 max_prefill=16, chunk_steps=8)
    rng = np.random.default_rng(2)
    reqs = _requests(2, rng, max_new=13)   # 13 = 1 (prefill) + 12; not 8k
    done = serve(eng, reqs)
    assert len(done) == 2
    for r in done:
        assert len(r.output) == 13


def test_chunk_stats_stacked_per_step():
    params = _params()
    raas = RaasConfig(policy="raas", budget_tokens=32, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                 max_prefill=16)
    rng = np.random.default_rng(3)
    for r in _requests(2, rng, max_new=20):
        eng.admit(r)
    eng.drain_prefill()
    _, out = eng._chunk_fn(
        eng.params, eng.cache, jnp.asarray(eng.last_token),
        jnp.asarray(eng.pos), jnp.asarray(eng.active),
        jnp.asarray(eng.n_emitted), jnp.asarray(eng.eos_id),
        jnp.asarray(eng.max_new), steps=6)
    assert out.stats.tokens_cached.shape == (6, 2)
    assert out.stats.pages_attended.shape == (6, 2)
    # O(L): never more tokens cached than the budget allows
    assert int(jnp.max(out.stats.tokens_cached)) <= raas.budget_tokens


# ---------------------------------------------------------------------------
# lane re-use isolation (admit -> finish -> re-admit)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["raas", "quest_raas"])
def test_lane_reuse_isolated_from_previous_occupant(policy):
    params = _params()
    raas = RaasConfig(policy=policy, budget_tokens=64, page_size=4)
    rng = np.random.default_rng(4)
    reqs = _requests(3, rng, max_new=10)

    # 3 requests through 2 lanes: request 2 re-uses a lane whose cache
    # rows were just vacated by request 0 or 1.
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                 max_prefill=16, chunk_steps=4)
    done = serve(eng, copy.deepcopy(reqs))
    assert len(done) == 3
    reused = next(r for r in done if r.uid == 2)

    # fresh engine, identical geometry, request 2 alone on a clean lane
    eng2 = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                  max_prefill=16, chunk_steps=4)
    fresh = copy.deepcopy(reqs[2])
    done2 = serve(eng2, [fresh])
    assert reused.output == fresh.output


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------
def test_kv_cache_bytes_counts_every_cache_array():
    params = _params()
    raas = RaasConfig(policy="raas", budget_tokens=32, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                 max_prefill=16)
    expected = 0
    kv_only = 0
    for pos_cache in eng.cache.per_pos:
        if pos_cache.attn is None:
            continue
        expected += sum(x.nbytes for x in jax.tree.leaves(pos_cache.attn))
        kv_only += (pos_cache.attn.k_pages.nbytes
                    + pos_cache.attn.v_pages.nbytes)
    assert eng.kv_cache_bytes() == expected
    # rep_min/rep_max + page metadata are real memory the old
    # accounting missed
    assert eng.kv_cache_bytes() > kv_only
