"""Unit + property tests for the paged cache and sparsity policies.

The hypothesis suite drives random decode traces through the cache and
asserts the system invariants that make RaaS the paper's contribution:

  * capacity never exceeds the O(L) budget (+ pinned prefill),
  * pinned (prefill) pages are never evicted,
  * RaaS evicts the page with the oldest timestamp among unpinned,
  * StreamingLLM == RaaS machinery with frozen priorities == sliding
    window over decode pages,
  * cache contents always mirror a token-level reference simulator.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency: the property tests below
    # skip cleanly when it is absent so collection never breaks.
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        def deco(fn):
            @_SKIP
            @functools.wraps(fn)
            def stub(*args, **kwargs):
                raise AssertionError("unreachable: test is skipped")
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core import policies
from repro.core.attention import decode_attend
from repro.core.policy_base import get_policy


def _mk_cache(n_slots, P=4, KV=2, hd=8, B=1):
    spec = pc.CacheSpec(n_slots=n_slots, page_size=P, n_kv_heads=KV,
                        head_dim=hd, dtype=jnp.float32)
    return pc.init_cache(spec, B), spec


def _rand_kv(rng, B=1, KV=2, hd=8):
    return (jnp.asarray(rng.standard_normal((B, KV, hd)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, KV, hd)), jnp.float32))


# ---------------------------------------------------------------------------
# paged cache unit tests
# ---------------------------------------------------------------------------
def test_ingest_prefill_ragged():
    cache, _ = _mk_cache(6, P=4, B=2)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((2, 10, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 10, 2, 8)), jnp.float32)
    lengths = jnp.array([10, 5])
    cache = pc.ingest_prefill(cache, k, v, lengths)
    np.testing.assert_array_equal(cache.page_len[0, :3], [4, 4, 2])
    np.testing.assert_array_equal(cache.page_len[1, :3], [4, 1, 0])
    assert bool(cache.pinned[0, :3].all())
    assert bool(cache.pinned[1, :2].all()) and not bool(cache.pinned[1, 2])
    np.testing.assert_array_equal(np.asarray(cache.tokens_cached()),
                                  [10, 5])
    # rep keys of page 0 match min/max of its 4 keys ([B, KV, S, hd])
    np.testing.assert_allclose(cache.rep_min[0, :, 0],
                               np.asarray(k[0, :4].min(0)), rtol=1e-6)
    np.testing.assert_allclose(cache.rep_max[0, :, 0],
                               np.asarray(k[0, :4].max(0)), rtol=1e-6)


def test_prefill_too_long_raises():
    cache, _ = _mk_cache(2, P=4)
    k = jnp.zeros((1, 12, 2, 8))
    with pytest.raises(ValueError, match="exceeds cache capacity"):
        pc.ingest_prefill(cache, k, k, jnp.array([12]))


def test_append_fills_pages_then_evicts_oldest():
    cache, _ = _mk_cache(3, P=2)
    rng = np.random.default_rng(1)
    # fill 3 pages = 6 tokens, priorities = arrival order (streaming)
    for i in range(6):
        k, v = _rand_kv(rng)
        cache, ev = pc.append_token(cache, k, v,
                                    cache.cur_len.astype(jnp.float32))
        assert int(ev[0]) == -1
    assert int(cache.tokens_cached()[0]) == 6
    # 7th token: page 0 (oldest priority) is evicted
    k, v = _rand_kv(rng)
    cache, ev = pc.append_token(cache, k, v,
                                cache.cur_len.astype(jnp.float32))
    assert int(ev[0]) == 0
    assert int(cache.tokens_cached()[0]) == 5  # lost 2, gained 1


def test_pinned_pages_never_evicted():
    cache, _ = _mk_cache(3, P=2)
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.standard_normal((1, 4, 2, 8)), jnp.float32)
    cache = pc.ingest_prefill(cache, k, k, jnp.array([4]))  # 2 pinned pages
    for i in range(8):
        kn, vn = _rand_kv(rng)
        cache, ev = pc.append_token(cache, kn, vn,
                                    cache.cur_len.astype(jnp.float32))
        # only the single decode slot (2) may rotate; prefill survives
        assert int(ev[0]) in (-1, 2)
    assert bool(cache.pinned[0, :2].all())
    assert int(cache.page_pos[0, 0]) == 0  # prefill still there


# ---------------------------------------------------------------------------
# RaaS selection rule
# ---------------------------------------------------------------------------
def test_raas_top_r_selects_half():
    cfg = RaasConfig(policy="raas", budget_tokens=64, page_size=4,
                     use_top_r=True, top_r=0.5)
    scores = jnp.asarray([[5.0, 1.0, 3.0, 2.0, 4.0, -1e30]])
    valid = jnp.asarray([[True] * 5 + [False]])
    sel = policies.raas_selected_mask(scores, valid, cfg)
    # ceil(0.5 * 5) = 3 -> top-3 scores: 5.0, 4.0, 3.0
    np.testing.assert_array_equal(
        np.asarray(sel[0]), [True, False, True, False, True, False])


def test_raas_alpha_rule():
    cfg = RaasConfig(policy="raas", budget_tokens=64, page_size=4,
                     use_top_r=False, alpha=0.01)
    scores = jnp.asarray([[10.0, 0.0, 9.0, -1e30]])
    valid = jnp.asarray([[True, True, True, False]])
    sel = policies.raas_selected_mask(scores, valid, cfg)
    assert bool(sel[0, 0]) and bool(sel[0, 2])
    assert not bool(sel[0, 1])  # prob(0 vs 10) << alpha
    assert not bool(sel[0, 3])


# ---------------------------------------------------------------------------
# property-based invariants (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    policy=st.sampled_from(["raas", "streaming", "h2o"]),
    budget_pages=st.integers(3, 6),
    prefill_len=st.integers(0, 6),
    n_decode=st.integers(1, 30),
    seed=st.integers(0, 10_000),
)
def test_policy_invariants(policy, budget_pages, prefill_len, n_decode,
                           seed):
    P, KV, hd, B = 4, 2, 8, 1
    cfg = RaasConfig(policy=policy, budget_tokens=budget_pages * P,
                     page_size=P, h2o_recent=4)
    n_slots = get_policy(cfg.policy).cache_slots(cfg, prefill_len + n_decode,
                                                 prefill_len)
    spec = pc.CacheSpec(n_slots, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(seed)
    if prefill_len:
        k = jnp.asarray(rng.standard_normal((B, prefill_len, KV, hd)),
                        jnp.float32)
        cache = pc.ingest_prefill(cache, k, k,
                                  jnp.full((B,), prefill_len))
    n_pre_pages = -(-prefill_len // P)
    H = 4
    for step in range(n_decode):
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        k, v = _rand_kv(rng, B, KV, hd)
        cache, ctx, stats = decode_attend(cache, q, k, v, cfg,
                                          has_prefill=prefill_len > 0)
        # -- invariant: O(L) capacity ----------------------------------
        assert int(cache.tokens_cached()[0]) <= spec.capacity_tokens
        assert cache.n_slots == n_slots  # static O(L) memory
        # -- invariant: pinned prefill intact --------------------------
        if prefill_len:
            assert bool(cache.pinned[0, :n_pre_pages].all())
            got = int(cache.page_len[0, :n_pre_pages].sum())
            assert got == prefill_len
        # -- invariant: output is finite -------------------------------
        assert bool(jnp.isfinite(ctx).all())
        # -- invariant: newest token always present ---------------------
        act = int(cache.active_slot[0])
        assert int(cache.page_len[0, act]) >= 1
        if not (policy == "streaming" and prefill_len == 0):
            # (streaming pins its first decode pages as the sink)
            assert not bool(cache.pinned[0, act])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_decode=st.integers(8, 24))
def test_streaming_is_sliding_window(seed, n_decode):
    """With frozen priorities, retained decode tokens are the most
    recent ones (modulo page granularity)."""
    P, KV, hd, B, H = 2, 1, 4, 1, 2
    cfg = RaasConfig(policy="streaming", budget_tokens=8, page_size=P)
    n_slots = policies.cache_slots(cfg, n_decode, 0)
    spec = pc.CacheSpec(n_slots, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(seed)
    for step in range(n_decode):
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        k, v = _rand_kv(rng, B, KV, hd)
        cache, _, _ = decode_attend(cache, q, k, v, cfg,
                                    has_prefill=False)
    pos = np.asarray(cache.page_pos[0])
    plen = np.asarray(cache.page_len[0])
    live = [(p, l) for p, l in zip(pos, plen) if l > 0]
    # sink pages (pos < sink_tokens) are pinned; the rest must be a
    # contiguous recent window.
    non_sink = sorted(p for p, _ in live if p >= cfg.sink_tokens)
    if len(non_sink) > 1:
        diffs = np.diff(non_sink)
        assert (diffs == P).all(), f"window not contiguous: {non_sink}"
        assert non_sink[-1] == (n_decode - 1) // P * P  # newest page


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_quest_attends_topk_only(seed):
    P, KV, hd, B, H = 2, 1, 4, 1, 2
    cfg = RaasConfig(policy="quest", budget_tokens=8, page_size=P,
                     quest_topk_pages=3)
    n_slots = policies.cache_slots(cfg, 20, 0)
    spec = pc.CacheSpec(n_slots, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(seed)
    for step in range(16):
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        k, v = _rand_kv(rng, B, KV, hd)
        cache, _, stats = decode_attend(cache, q, k, v, cfg,
                                        has_prefill=False)
        assert int(stats.pages_attended[0]) <= 3
        assert int(stats.evicted_slot[0]) == -1   # quest never evicts
    assert int(cache.tokens_cached()[0]) == 16    # O(N) retention


def test_quest_raas_hybrid():
    """Beyond-paper extension the paper recommends (§Limitations):
    Quest top-k over prefill pages + RaaS budget over decode pages.
    Memory O(N_prefill + L); prefill pages never evicted; attention
    touches k prefill pages + all decode pages."""
    P, KV, hd, B, H = 2, 1, 4, 1, 2
    prefill_len, budget = 8, 8        # 4 prefill pages, 4 decode pages
    cfg = RaasConfig(policy="quest_raas", budget_tokens=budget,
                     page_size=P, quest_topk_pages=2,
                     prefill_pages_hint=prefill_len // P)
    n_slots = policies.cache_slots(cfg, 40, prefill_len)
    assert n_slots == 4 + 4
    spec = pc.CacheSpec(n_slots, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(5)
    k = jnp.asarray(rng.standard_normal((B, prefill_len, KV, hd)),
                    jnp.float32)
    cache = pc.ingest_prefill(cache, k, k, jnp.array([prefill_len]))
    for step in range(20):
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        kn, vn = _rand_kv(rng, B, KV, hd)
        cache, ctx, stats = decode_attend(cache, q, kn, vn, cfg)
        assert bool(jnp.isfinite(ctx).all())
        # attention = k prefill pages + live decode pages
        n_dec_live = int((cache.page_len[0, 4:] > 0).sum())
        assert int(stats.pages_attended[0]) <= 2 + n_dec_live
    # prefill retained in memory, decode capped at the RaaS budget
    assert int(cache.page_len[0, :4].sum()) == prefill_len
    assert int(cache.page_len[0, 4:].sum()) <= budget


def test_h2o_recent_window_protected():
    P, KV, hd, B, H = 1, 1, 4, 1, 1   # token-granular (page_size=1)
    cfg = RaasConfig(policy="h2o", budget_tokens=6, page_size=P,
                     h2o_recent=3)
    spec = pc.CacheSpec(6, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(3)
    for step in range(12):
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        k, v = _rand_kv(rng, B, KV, hd)
        cache, _, _ = decode_attend(cache, q, k, v, cfg,
                                    has_prefill=False)
        pos = np.asarray(cache.page_pos[0])
        live = pos[np.asarray(cache.page_len[0]) > 0]
        # the h2o_recent most recent tokens must all be cached
        for t in range(max(0, step - cfg.h2o_recent + 1), step + 1):
            assert t in live, f"recent token {t} evicted at step {step}"
