"""Unit tests for the HLO collective parser and roofline math.

The passes live in :mod:`repro.analysis.hlo` (the static-analysis
package); ``repro.launch.hlo_analysis`` remains a back-compat shim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H

SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,4096]{1,0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[32,32]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = f32[16,16]{1,0} all-to-all(%w), replica_groups={{0,1,2,3}}, dimensions={0}
  %ard = f32[8] all-reduce-done(%q)
}
"""


def test_collective_bytes_parsing():
    out = H.collective_bytes(SAMPLE)
    g = 16
    ag = 128 * 4096 * 4 * (g - 1) / g
    ar = 1024 * 2 * 2 * 3 / 4
    rs = 64 * 4 * 1
    cp = 32 * 32 * 4
    aa = 16 * 16 * 4 * 3 / 4
    assert out["all-gather"] == pytest.approx(ag)
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["reduce-scatter"] == pytest.approx(rs)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["all-to-all"] == pytest.approx(aa)
    assert out["total"] == pytest.approx(ag + ar + rs + cp + aa)


def test_counts():
    c = H.count_collectives(SAMPLE)
    assert c["all-gather"] == 1 and c["all-reduce"] == 1
    assert c["collective-permute"] == 1


def test_roofline_terms():
    t = H.roofline_terms(197e12, 819e9, 50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)


def test_real_hlo_roundtrip():
    """Parse collectives out of an actually-compiled sharded program.

    Used to skip silently below 2 devices — which meant it NEVER ran in
    CI.  Now routed through the forced-host-device harness
    (tests/mdev_harness.py): in-process on a multi-device run, in a
    forced-2-device subprocess everywhere else."""
    from mdev_harness import run_case
    run_case("case_hlo_collectives_roundtrip", ndev=2)


def test_shape_bytes_tuple():
    assert H._shape_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2


def test_launch_shim_reexports():
    """launch.hlo_analysis stays importable and IS the analysis module's
    surface (dryrun + older callers go through it)."""
    from repro.launch import hlo_analysis as shim
    assert shim.collective_bytes is H.collective_bytes
    assert shim.count_collectives is H.count_collectives
    assert shim.roofline_terms is H.roofline_terms
    assert shim.PEAK_FLOPS_BF16 == H.PEAK_FLOPS_BF16
