"""Zero-copy paged prefill: parity, HLO and jit-cache regression tests.

What is pinned down for the paged flash-prefill kernel rewrite:
  * **Ragged chunk-resume parity** — jnp oracle vs Pallas interpret
    across lanes resumed at different offsets, with ragged live
    lengths, a ``chunk_lens = 0`` ride-along lane, and every
    ``ctx_pages`` bucket that covers the live region (the Pallas output
    must be *bit-identical* across buckets: dead blocks contribute
    exactly nothing).
  * **Bit-exactness vs the token-major path** — the ``impl='jnp'``
    paged entry reproduces the pre-kernel gather-then-dense-flash path
    byte for byte at equal ``ctx_pages`` (it *is* that computation,
    relocated into the oracle), and the Pallas paged kernel matches the
    dense Pallas kernel run over a gathered copy.
  * **HLO zero-copy regression** — the compiled Pallas prefill chunk
    contains no float transpose/gather at or above the size of the
    ctx-region token-major copy the old path materialized (same
    methodology as tests/test_zero_copy.py for decode).
  * **Jit-cache bound** — power-of-two ``ctx_pages`` bucketing: a long
    prompt ingested over many chunk boundaries compiles at most
    O(log prefill_pages) prefill variants.
  * **Grid-trace dead-block skip** — ``block_is_live`` (the predicate
    both prefill kernels stage into ``@pl.when``) traced over a whole
    grid never computes a block wholly past a lane's ``kv_len`` or
    causal frontier, and agrees with the analytic cost model's live
    count.
  * **Sharded paged prefill** — byte parity and identical analytic
    prefill traffic under a lane-sharded mesh (body in
    tests/mdev_cases.py, executed everywhere via tests/mdev_harness.py).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from mdev_harness import run_case
from repro.analysis.hlo import kv_copy_ops as _copy_ops_at_least

from repro.config import ModelConfig, RaasConfig
from repro.core import paged_cache as pc
from repro.kernels import ops
from repro.kernels.flash_prefill import block_is_live
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)
RAAS = RaasConfig(policy="raas", budget_tokens=64, page_size=4)


def _ragged_cache(rng, B=3, KV=2, hd=16, P=4, S=24, n_tok=48,
                  lengths=(37, 21, 0)):
    spec = pc.CacheSpec(S, P, KV, hd, jnp.float32)
    cache = pc.init_cache(spec, B)
    k = jnp.asarray(rng.standard_normal((B, n_tok, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n_tok, KV, hd)), jnp.float32)
    return pc.ingest_prefill(cache, k, v, jnp.asarray(lengths, jnp.int32))


# ---------------------------------------------------------------------------
# kernel parity across ragged chunk-resume offsets
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ctx_pages", [10, 12, 16])
def test_paged_prefill_parity_ragged_offsets(ctx_pages):
    """Lanes mid-prompt at different offsets, a ragged final page, and
    a ``chunk_lens = 0`` ride-along lane (lane 2: kv_len 0 — every one
    of its blocks is dead): oracle vs Pallas interpret."""
    rng = np.random.default_rng(0)
    cache = _ragged_cache(rng)
    B, C, H, hd = 3, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, C, H, hd)), jnp.float32)
    off = jnp.asarray([32, 16, 0], jnp.int32)
    lim = jnp.asarray([37, 21, 0], jnp.int32)    # lane 2 rides along
    ref = ops.paged_flash_prefill(q, cache.k_pages, cache.v_pages, 0.25,
                                  off, lim, ctx_pages=ctx_pages,
                                  impl="jnp")
    got = ops.paged_flash_prefill(q, cache.k_pages, cache.v_pages, 0.25,
                                  off, lim, ctx_pages=ctx_pages,
                                  impl="pallas_interpret",
                                  block_q=8, block_k=8)
    # only live query rows are meaningful (dead rows attend nothing)
    live = np.asarray(off)[:, None] + np.arange(C)[None] \
        < np.asarray(lim)[:, None]
    err = np.abs(np.where(live[..., None, None],
                          np.asarray(ref - got), 0.0)).max()
    assert float(err) < 2e-5
    # ride-along lane: the kernel skips every block -> exact zeros
    assert np.array_equal(np.asarray(got)[2], np.zeros((C, H, hd)))


def test_paged_prefill_pallas_bucket_invariant():
    """Dead blocks contribute exactly nothing: the Pallas output is
    bit-identical across every ``ctx_pages`` bucket covering the live
    region — the engine's bucketing can never perturb a logit."""
    rng = np.random.default_rng(1)
    cache = _ragged_cache(rng)
    q = jnp.asarray(rng.standard_normal((3, 8, 4, 16)), jnp.float32)
    off = jnp.asarray([32, 16, 0], jnp.int32)
    lim = jnp.asarray([37, 21, 0], jnp.int32)
    outs = [np.asarray(ops.paged_flash_prefill(
        q, cache.k_pages, cache.v_pages, 0.25, off, lim, ctx_pages=cp,
        impl="pallas_interpret", block_q=8, block_k=8))
        for cp in (10, 12, 16, 24)]
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


def test_paged_prefill_bit_exact_vs_token_major_path():
    """The paged entry at ``impl='jnp'`` IS the pre-PR token-major path
    (gather + dense flash oracle), byte for byte; the Pallas paged
    kernel matches the dense Pallas kernel over a gathered copy."""
    rng = np.random.default_rng(2)
    cache = _ragged_cache(rng, lengths=(37, 21, 48))
    B, C, H, KV, hd, P = 3, 8, 4, 2, 16, 4
    ctx_pages = 12
    q = jnp.asarray(rng.standard_normal((B, C, H, hd)), jnp.float32)
    off = jnp.asarray([32, 16, 40], jnp.int32)
    lim = jnp.asarray([37, 21, 48], jnp.int32)
    # the pre-PR blocks.block_prefill_chunk body, verbatim
    kc = cache.k_pages[:, :, :ctx_pages].transpose(0, 2, 3, 1, 4) \
        .reshape(B, ctx_pages * P, KV, hd)
    vc = cache.v_pages[:, :, :ctx_pages].transpose(0, 2, 3, 1, 4) \
        .reshape(B, ctx_pages * P, KV, hd)
    old = ops.flash_prefill(q, kc, vc, 0.25, q_offset=off, kv_len=lim,
                            impl="jnp")
    new = ops.paged_flash_prefill(q, cache.k_pages, cache.v_pages, 0.25,
                                  off, lim, ctx_pages=ctx_pages,
                                  impl="jnp")
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))

    dense_pl = ops.flash_prefill(q, kc, vc, 0.25, q_offset=off,
                                 kv_len=lim, impl="pallas_interpret",
                                 block_q=8, block_k=8)
    paged_pl = ops.paged_flash_prefill(q, cache.k_pages, cache.v_pages,
                                       0.25, off, lim,
                                       ctx_pages=ctx_pages,
                                       impl="pallas_interpret",
                                       block_q=8, block_k=8)
    live = np.asarray(off)[:, None] + np.arange(C)[None] \
        < np.asarray(lim)[:, None]
    err = np.abs(np.where(live[..., None, None],
                          np.asarray(dense_pl - paged_pl), 0.0)).max()
    assert float(err) < 2e-5


# ---------------------------------------------------------------------------
# HLO zero-copy regression on the compiled prefill chunk
# ---------------------------------------------------------------------------
def test_pallas_prefill_chunk_hlo_has_no_kv_copy():
    """The Pallas prefill chunk must read the page-major cache in
    place: no float transpose/gather at or above the size of the old
    token-major ctx-region copy may appear in the optimized HLO (the
    chunk's own O(C) ingest reshape is far below the threshold)."""
    B, C, max_prefill, max_seq = 2, 8, 64, 128
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    cache = M.init_model_cache(TINY, RAAS, B, max_seq,
                               prefill_len=max_prefill)
    ctx_pages = max_prefill // RAAS.page_size            # 16 pages
    toks = jnp.zeros((B, C), jnp.int32)
    cl = jnp.full((B,), C, jnp.int32)
    start = jnp.zeros((B,), jnp.int32)
    fn = jax.jit(lambda p, c, t, l, s: M.prefill_chunk(
        p, TINY, t, l, s, c, ctx_pages=ctx_pages,
        impl="pallas_interpret"))
    comp = fn.lower(params, cache, toks, cl, start).compile()
    ctx_copy_elems = B * ctx_pages * RAAS.page_size \
        * TINY.n_kv_heads * TINY.head_dim
    bad = _copy_ops_at_least(comp.as_text(), ctx_copy_elems)
    assert not bad, f"KV-sized copies in pallas prefill chunk: {bad}"


def test_oracle_prefill_chunk_gather_is_o_ctx_not_o_s():
    """The jnp oracle may gather the ctx region (inherent to jnp) but
    must never touch slots beyond ``ctx_pages`` — with a cache far
    larger than the prefill region, no full-cache-sized copy appears."""
    B, C, max_prefill = 2, 8, 16
    # huge decode budget -> many slots beyond the 4-page prefill region
    raas = RaasConfig(policy="raas", budget_tokens=192, page_size=4)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    cache = M.init_model_cache(TINY, raas, B, 256,
                               prefill_len=max_prefill)
    S = cache.per_pos[0].attn.k_pages.shape[3]
    ctx_pages = max_prefill // raas.page_size
    assert S > 2 * ctx_pages
    fn = jax.jit(lambda p, c, t, l, s: M.prefill_chunk(
        p, TINY, t, l, s, c, ctx_pages=ctx_pages, impl="jnp"))
    comp = fn.lower(params, cache, jnp.zeros((B, C), jnp.int32),
                    jnp.full((B,), C, jnp.int32),
                    jnp.zeros((B,), jnp.int32)).compile()
    full_cache_elems = B * TINY.n_kv_heads * S * raas.page_size \
        * TINY.head_dim
    bad = _copy_ops_at_least(comp.as_text(), full_cache_elems)
    assert not bad, f"full-cache copies in oracle prefill chunk: {bad}"


# ---------------------------------------------------------------------------
# ctx_pages bucketing: jit-cache bound
# ---------------------------------------------------------------------------
def test_ctx_pages_bucketing_bounds_prefill_compilations():
    """A 60-token prompt ingested 4 tokens per dispatch crosses 15
    chunk boundaries; power-of-two bucketing must compile at most
    log2(prefill_pages) + 1 prefill variants (and strictly fewer than
    the dispatch count), while still serving exactly."""
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    eng = Engine(params, TINY, RAAS, batch_slots=2, max_seq=128,
                 max_prefill=64, prefill_chunk=4, chunk_steps=4)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, TINY.vocab_size, size=60).astype(np.int32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    done = serve(eng, [req])
    assert len(done) == 1 and len(req.output) == 4
    assert eng.prefill_dispatches == 15
    prefill_pages = 64 // RAAS.page_size                  # 16
    bound = prefill_pages.bit_length() + 1                # log2 + 1
    assert eng.prefill_traces <= bound, \
        (eng.prefill_traces, bound)
    assert eng.prefill_traces < eng.prefill_dispatches
    # the analytic accounting ran per dispatch, paged strictly cheaper
    assert 0 < eng.prefill_kv_bytes < eng.prefill_kv_bytes_gather


def test_long_prompt_byte_parity_vs_sequential_reference():
    """Bit-exact long-prompt byte parity: the same mixed workload
    served continuously (bucketed paged prefill interleaving with
    decode) and sequentially (one request at a time through the same
    engine geometry) must emit identical bytes."""
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    rng = np.random.default_rng(4)
    lens = [40, 3, 57, 17]
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        size=n).astype(np.int32),
                    max_new_tokens=6) for i, n in enumerate(lens)]

    def mk():
        return Engine(params, TINY, RAAS, batch_slots=2, max_seq=160,
                      max_prefill=64, prefill_chunk=8, chunk_steps=4)

    cont = copy.deepcopy(reqs)
    done = serve(mk(), cont)
    assert len(done) == len(reqs)
    seq_eng = mk()
    for r in reqs:
        seq_eng.admit(r)
        seq_eng.drain_prefill()
        while seq_eng.has_active():
            seq_eng.step_chunk()
    for a, b in zip(sorted(cont, key=lambda r: r.uid),
                    sorted(reqs, key=lambda r: r.uid)):
        assert a.output == b.output, f"uid {a.uid} diverged"


# ---------------------------------------------------------------------------
# grid-trace: dead-tail blocks are skipped by construction
# ---------------------------------------------------------------------------
def test_dead_tail_block_skip_grid_trace():
    """Trace ``block_is_live`` — the exact predicate both prefill
    kernels stage into ``@pl.when`` — over a whole (lane, qi, ki) grid:
    no computed block may start at or past the lane's ``kv_len``
    (ragged dead tail) or past its causal frontier, every causally
    needed live block IS computed, and the per-(lane, qi) live count
    matches the analytic cost model's."""
    bQ, bT = 8, 8
    Sq, ctx_tokens = 16, 64
    nQ, nK = Sq // bQ, ctx_tokens // bT
    offsets = [0, 24, 40, 0]
    kv_lens = [8, 29, 40, 0]                  # incl. a dead lane
    H, KV, hd, itemsize = 4, 2, 16, 4
    live_counts = []
    for off, lim in zip(offsets, kv_lens):
        for qi in range(nQ):
            last_q = qi * bQ + (bQ - 1) + off
            computed = [ki for ki in range(nK)
                        if block_is_live(ki * bT, last_q, lim)]
            for ki in computed:
                assert ki * bT < lim, \
                    f"dead-tail block {ki} computed (kv_len {lim})"
                assert ki * bT <= last_q, \
                    f"causal-future block {ki} computed"
            # completeness: every block holding a live attendable key
            for ki in range(nK):
                if ki * bT < min(lim, last_q + 1):
                    assert ki in computed, f"live block {ki} skipped"
            live_counts.append(max(len(computed), 1))
    cost = ops.flash_prefill_cost(
        H=H, KV=KV, hd=hd, Sq=Sq, ctx_tokens=ctx_tokens,
        q_offset=np.asarray(offsets), kv_len=np.asarray(kv_lens),
        block_q=bQ, block_kv=bT, itemsize=itemsize)
    kv_bytes = sum(live_counts) * H * bT * hd * itemsize * 2
    qo_bytes = 2 * len(offsets) * H * Sq * hd * itemsize
    assert cost["bytes_accessed"] == kv_bytes + qo_bytes \
        + 2 * len(offsets) * 4


# ---------------------------------------------------------------------------
# sharded paged prefill (multi-device case body in mdev_cases.py)
# ---------------------------------------------------------------------------
def test_sharded_paged_prefill_byte_parity():
    run_case("case_paged_prefill_sharded")
