"""Prefix caching & multi-turn KV sessions, engine level.

The page-pool aliasing machinery (``repro.core.page_pool``) is unit-
tested in tests/test_page_pool.py; here the *serving contract* is
pinned end to end:

  * a fleet sharing a prompt prefix produces outputs byte-identical to
    a ``prefix_caching=False`` baseline while ingesting only the
    unshared suffixes (``prefill_tokens`` collapses by exactly
    ``prefix_cached_tokens``),
  * a multi-turn conversation resumed via ``Request.session_id``
    matches a cold engine re-prefilling the full history, ingesting
    only the tokens past the parked pages,
  * the admission guards: re-admitting a served Request raises, and a
    prompt needing more pages than the policy provisions raises
    instead of silently clipping.
"""
import copy

import jax
import numpy as np
import pytest

from repro.config import ModelConfig, RaasConfig, ServeConfig
from repro.core import page_pool as pool
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)
RAAS = RaasConfig(policy="raas", budget_tokens=64, page_size=4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), TINY)


def _engine(params, caching=True):
    cfg = ServeConfig(batch_slots=2, max_seq=128, max_prefill=32,
                      prefill_chunk=8, chunk_steps=4,
                      prefix_caching=caching)
    return Engine(params, TINY, RAAS, cfg)


# ---------------------------------------------------------------------------
# shared-prefix fleet: byte parity + prefill collapse
# ---------------------------------------------------------------------------
def _fleet(rng, n=4, prefix_len=24, suffix_len=4, max_new=10):
    prefix = rng.integers(0, 128, size=prefix_len).astype(np.int32)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [prefix,
                         rng.integers(0, 128, size=suffix_len)
                            .astype(np.int32)]),
                    max_new_tokens=max_new)
            for i in range(n)]


def test_shared_prefix_fleet_matches_uncached_baseline(params):
    rng = np.random.default_rng(7)
    reqs = _fleet(rng)
    base = copy.deepcopy(reqs)

    eng_c = _engine(params, caching=True)
    eng_b = _engine(params, caching=False)
    serve(eng_c, reqs)
    serve(eng_b, base)

    for rc, rb in zip(reqs, base):
        assert rc.done and rb.done
        assert rc.output == rb.output, rc.uid
    # later fleet members rode the first one's registered pages
    assert eng_c.prefix_mounts + eng_c.prefix_clones >= 1
    assert eng_c.prefix_cached_tokens > 0
    # prefill collapsed to exactly the un-cached tokens
    assert eng_c.prefill_tokens \
        == eng_b.prefill_tokens - eng_c.prefix_cached_tokens


def test_uncached_engine_queues_no_pool_work(params):
    rng = np.random.default_rng(3)
    eng = _engine(params, caching=False)
    serve(eng, _fleet(rng, n=2))
    assert eng.pool_dispatches == 0
    assert eng.prefix_cached_tokens == 0
    assert eng.sessions == {}


# ---------------------------------------------------------------------------
# multi-turn sessions: resume == cold re-prefill, byte-identical
# ---------------------------------------------------------------------------
def test_session_resume_matches_cold_engine(params):
    rng = np.random.default_rng(11)
    sid = pool.generate_session_id()
    eng = _engine(params, caching=True)

    turn1 = rng.integers(0, 128, size=12).astype(np.int32)
    r1 = Request(uid=0, prompt=turn1, max_new_tokens=8, session_id=sid)
    serve(eng, [r1])
    assert r1.done and len(r1.output) == 8

    # the follow-up prompt is the whole conversation so far + new tokens
    hist = np.concatenate([turn1, np.asarray(r1.output, np.int32)])
    follow = rng.integers(0, 128, size=7).astype(np.int32)
    prompt2 = np.concatenate([hist, follow])

    p0 = eng.prefill_tokens
    c0 = eng.prefix_cached_tokens
    r2 = Request(uid=1, prompt=prompt2, max_new_tokens=8, session_id=sid)
    serve(eng, [r2])
    ingested = eng.prefill_tokens - p0
    cached = eng.prefix_cached_tokens - c0

    assert eng.session_hits >= 1
    # only the tokens past the parked full pages were re-prefilled.
    # The final sampled token is returned without being written back,
    # so the park covers the full pages of len(hist) - 1 tokens.
    P = RAAS.page_size
    assert cached == ((len(hist) - 1) // P) * P
    assert 0 < ingested < len(prompt2)
    assert ingested == len(prompt2) - cached

    # a cold engine prefilling the full turn-2 prompt from scratch
    # (caching off) must produce the exact same continuation
    cold = _engine(params, caching=False)
    rc = Request(uid=2, prompt=prompt2.copy(), max_new_tokens=8)
    serve(cold, [rc])
    assert r2.output == rc.output


def test_session_id_is_validated_at_admission(params):
    eng = _engine(params, caching=True)
    bad = Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                  max_new_tokens=4, session_id="not-a-session-id")
    with pytest.raises(ValueError, match="session_id"):
        eng.admit(bad)


# ---------------------------------------------------------------------------
# admission guards
# ---------------------------------------------------------------------------
def test_readmitting_served_request_raises(params):
    eng = _engine(params, caching=True)
    r = Request(uid=5, prompt=np.arange(8, dtype=np.int32),
                max_new_tokens=4)
    serve(eng, [r])
    assert r.done
    with pytest.raises(ValueError, match="already served"):
        eng.admit(r)


def test_prompt_beyond_policy_slots_is_rejected(params):
    eng = _engine(params, caching=True)
    # built-in policies provision cache_slots >= prefill pages, so
    # shrink the bound to exercise the guard (page_size=4: 12 tokens
    # need 3 pages > 2 slots)
    eng.n_slots = 2
    with pytest.raises(ValueError, match="n_slots"):
        eng.admit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                          max_new_tokens=4))
