"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures (+ the paper's own eval
model), instantiate the REDUCED variant of the same family (<= 2-ish
periods, d_model <= 512, <= 4 experts) and run:
  * one train step (loss finite, grads applied, shapes right),
  * one prefill + two decode steps under the RaaS policy,
asserting output shapes and no NaNs, on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RaasConfig, RunConfig, get_config, list_archs
from repro.launch.train import make_train_step
from repro.models import model as M
from repro.optim import adamw

ARCHS = list(list_archs())


def _reduced(arch):
    cfg = get_config(arch).reduced(n_layers=2, d_model=128, n_experts=4,
                                   vocab=128)
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = _reduced(arch)
    run = RunConfig(arch=arch, total_steps=10, warmup_steps=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    B, T = 2, 32
    tok_shape = (B, T) if cfg.n_codebooks == 1 else (B, T, cfg.n_codebooks)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), tok_shape, 0,
                                     cfg.vocab_size),
        "loss_mask": jnp.ones((B, T), jnp.float32),
    }
    if cfg.frontend:
        batch["prefix_emb"] = 0.01 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_prefix_tokens, cfg.d_model))
    step = make_train_step(cfg, run, capacity_factor=4.0)
    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["gnorm"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert float(jnp.abs(l0 - l1).max()) > 0
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_smoke(arch):
    cfg = _reduced(arch)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, pre, T = 2, 12, 20
    tok_shape = (B, pre) if cfg.n_codebooks == 1 \
        else (B, pre, cfg.n_codebooks)
    tokens = jax.random.randint(jax.random.PRNGKey(1), tok_shape, 0,
                                cfg.vocab_size)
    prefix = None
    if cfg.frontend:
        prefix = 0.01 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_prefix_tokens, cfg.d_model))
    cache = M.init_model_cache(cfg, raas, B, max_seq_len=T + 8,
                               prefill_len=pre + cfg.n_prefix_tokens)
    cache, logits = M.prefill(params, cfg, tokens,
                              jnp.full((B,), pre), cache,
                              prefix_emb=prefix)
    want = (B, cfg.vocab_size) if cfg.n_codebooks == 1 \
        else (B, cfg.n_codebooks, cfg.vocab_size)
    assert logits.shape == want, arch
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(pre, pre + 2):
        pos = jnp.full((B,), t + cfg.n_prefix_tokens, jnp.int32)
        cache, logits = M.decode_step(params, cfg, tok, pos, cache, raas)
        assert logits.shape == want, arch
        assert bool(jnp.isfinite(logits).all()), arch
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_full_configs_match_assignment_table():
    """The FULL configs must carry the exact assigned hyperparameters."""
    table = {
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    }
    for arch, (L, D, H, KV, FF, V) in table.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == D, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == KV, arch
        ff = cfg.moe.d_ff if (cfg.d_ff == 0 and cfg.moe) else cfg.d_ff
        assert ff == FF, arch
        assert cfg.vocab_size == V, arch
    # MoE / SSM structure
    assert get_config("olmoe-1b-7b").moe.n_experts == 64
    assert get_config("olmoe-1b-7b").moe.top_k == 8
    assert get_config("kimi-k2-1t-a32b").moe.n_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    jamba = get_config("jamba-1.5-large-398b")
    assert jamba.moe.n_experts == 16 and jamba.moe.top_k == 2
    mixers = [m for m, _ in jamba.period]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    assert get_config("mamba2-780m").mamba.d_state == 128
    assert get_config("musicgen-medium").n_codebooks == 4


def test_param_counts_plausible():
    """Sanity: derived parameter counts are in the advertised ballpark."""
    expect = {
        "qwen3-8b": (6e9, 10e9),
        "yi-34b": (30e9, 40e9),
        "internlm2-20b": (17e9, 24e9),
        "jamba-1.5-large-398b": (300e9, 450e9),
        "olmoe-1b-7b": (5e9, 9e9),
        "mamba2-780m": (0.5e9, 1.1e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "smollm-360m": (0.25e9, 0.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # active params for the MoEs
    assert 25e9 < get_config("kimi-k2-1t-a32b").n_active_params() < 40e9
    assert 0.8e9 < get_config("olmoe-1b-7b").n_active_params() < 1.7e9
