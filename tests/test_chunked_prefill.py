"""Chunked-prefill continuous batching tests.

Covers the acceptance criteria of the chunked-prefill refactor:
  * multi-chunk cache ingest is bit-identical to one-shot ingest,
  * ``prefill_chunk`` x N is bit-identical to one-shot ``prefill``
    (same last logits, same cache bytes),
  * the flash kernel's per-lane chunk-resume mask (array q_offset /
    kv_len) matches the jnp oracle,
  * prompts longer than the per-dispatch chunk — including longer than
    the old engine's one-shot padding — serve to completion with output
    identical to the single-request reference path (the old engine
    silently truncated them),
  * over-capacity prompts are rejected loudly at admission,
  * more requests than slots with mixed prompt lengths all complete and
    match their solo runs,
  * admission genuinely overlaps decode: a lane keeps emitting while
    another lane's long prompt is still being ingested, with no effect
    on its output,
  * stopping conditions are honored at admission (max_new_tokens=1 and
    immediate EOS never occupy a decode lane),
  * serving accounting is honest: emitted-token counts come from the
    device-side mask, and steps_executed does not count dead tail steps
    of a chunk.
"""
import copy

import jax.numpy as jnp
import numpy as np
import pytest

import jax
from repro.config import ModelConfig, RaasConfig
from repro.core import paged_cache as pc
from repro.kernels import ops
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)
RAAS = RaasConfig(policy="raas", budget_tokens=64, page_size=4)


def _params():
    return M.init_params(jax.random.PRNGKey(0), TINY)


def _engine(params, *, batch_slots=2, max_seq=160, max_prefill=48,
            prefill_chunk=8, chunk_steps=4, raas=RAAS):
    return Engine(params, TINY, raas, batch_slots=batch_slots,
                  max_seq=max_seq, max_prefill=max_prefill,
                  prefill_chunk=prefill_chunk, chunk_steps=chunk_steps)


def _prompt(rng, n):
    return rng.integers(0, TINY.vocab_size, size=n).astype(np.int32)


def _solo_reference(params, prompt, max_new, *, max_seq=160,
                    max_prefill=48, eos_id=None):
    """The unbatched single-request path: one-shot ``M.prefill`` padded
    to the lane capacity, then ``decode_step`` per token with host-side
    argmax — the pre-engine reference loop."""
    cache = M.init_model_cache(TINY, RAAS, 1, max_seq,
                               prefill_len=max_prefill)
    padded = np.zeros((1, max_prefill), np.int32)
    padded[0, :len(prompt)] = prompt
    cache, logits = M.prefill(params, TINY, jnp.asarray(padded),
                              jnp.asarray([len(prompt)], jnp.int32), cache)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_new and out[-1] != eos_id and pos < max_seq - 1:
        cache, logits = M.decode_step(
            params, TINY, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), cache, RAAS)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# cache / model-level chunk-resume parity
# ---------------------------------------------------------------------------
def test_multi_chunk_ingest_matches_oneshot():
    B, KV, hd, P, S = 2, 2, 8, 4, 16
    spec = pc.CacheSpec(S, P, KV, hd, jnp.float32)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, 48, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, 48, KV, hd)), jnp.float32)
    lengths = jnp.asarray([37, 21], jnp.int32)   # ragged, not page-aligned

    one = pc.ingest_prefill(pc.init_cache(spec, B), k[:, :40], v[:, :40],
                            lengths)
    chunked = pc.init_cache(spec, B)
    C = 8                                        # page multiple
    for c0 in range(0, 48, C):
        cl = jnp.clip(lengths - c0, 0, C)
        chunked = pc.ingest_prefill_chunk(chunked, k[:, c0:c0 + C],
                                          v[:, c0:c0 + C], cl)
    for f in pc.PagedCache._fields:
        np.testing.assert_array_equal(np.asarray(getattr(one, f)),
                                      np.asarray(getattr(chunked, f)),
                                      err_msg=f)


def test_ingest_chunk_zero_length_is_noop():
    spec = pc.CacheSpec(8, 4, 2, 8, jnp.float32)
    rng = np.random.default_rng(1)
    cache = pc.init_cache(spec, 2)
    k = jnp.asarray(rng.standard_normal((2, 8, 2, 8)), jnp.float32)
    cache = pc.ingest_prefill_chunk(cache, k, k,
                                    jnp.asarray([8, 0], jnp.int32))
    # lane 1 untouched, bit-exactly
    fresh = pc.init_cache(spec, 2)
    for f in pc.PagedCache._fields:
        np.testing.assert_array_equal(np.asarray(getattr(cache, f))[1],
                                      np.asarray(getattr(fresh, f))[1],
                                      err_msg=f)
    assert int(cache.cur_len[0]) == 8 and int(cache.cur_len[1]) == 0


def test_flash_prefill_per_lane_chunk_resume_mask():
    """Array q_offset / kv_len (the chunk-resume mask): Pallas
    interpret vs the jnp oracle, lanes at different progress."""
    rng = np.random.default_rng(2)
    B, Sq, Skv, H, KV, hd = 2, 8, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)), jnp.float32)
    off = jnp.asarray([0, 24], jnp.int32)
    lim = jnp.asarray([8, 29], jnp.int32)        # lane 1 mid-prompt, ragged
    ref = ops.flash_prefill(q, k, v, 0.25, q_offset=off, kv_len=lim,
                            impl="jnp")
    got = ops.flash_prefill(q, k, v, 0.25, q_offset=off, kv_len=lim,
                            impl="pallas_interpret", block_q=8, block_k=16)
    # only live query rows are meaningful (lane 1's rows past its chunk
    # attend nothing)
    live = np.asarray(off[:, None] + jnp.arange(Sq)[None] < lim[:, None])
    err = jnp.abs(jnp.where(jnp.asarray(live)[..., None, None],
                            ref - got, 0.0)).max()
    assert float(err) < 2e-5


def test_prefill_chunk_matches_oneshot_prefill():
    params = _params()
    B, max_prefill, max_seq, C = 2, 40, 96, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 128, (B, max_prefill)), jnp.int32)
    plens = jnp.asarray([37, 21], jnp.int32)

    cache0 = M.init_model_cache(TINY, RAAS, B, max_seq,
                                prefill_len=max_prefill)
    ref_cache, ref_logits = M.prefill(params, TINY, toks, plens, cache0)

    ctx_pages = -(-max_prefill // RAAS.page_size)
    cache = M.init_model_cache(TINY, RAAS, B, max_seq,
                               prefill_len=max_prefill)
    logits = None
    for c0 in range(0, max_prefill, C):
        cl = jnp.clip(plens - c0, 0, C)
        start = jnp.minimum(jnp.full((B,), c0, jnp.int32), plens)
        cache, lg = M.prefill_chunk(params, TINY, toks[:, c0:c0 + C], cl,
                                    start, cache, ctx_pages=ctx_pages)
        done_now = (c0 < plens) & (plens <= c0 + C)
        logits = lg if logits is None else jnp.where(done_now[:, None],
                                                     lg, logits)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref_logits))
    for pp_ref, pp_c in zip(ref_cache.per_pos, cache.per_pos):
        for f in pc.PagedCache._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(pp_ref.attn, f)),
                np.asarray(getattr(pp_c.attn, f)), err_msg=f)


# ---------------------------------------------------------------------------
# serving: long prompts, capacity, mixed workloads
# ---------------------------------------------------------------------------
def test_long_prompt_serves_and_matches_reference():
    """A 40-token prompt through 8-token prefill chunks: the old engine
    would have truncated anything beyond its one-shot pad; now it must
    serve to completion with output identical to the unbatched
    single-request reference path."""
    params = _params()
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, 40)
    ref = _solo_reference(params, prompt, max_new=12)

    eng = _engine(params, prefill_chunk=8)
    req = Request(uid=0, prompt=prompt, max_new_tokens=12)
    done = serve(eng, [req])
    assert len(done) == 1 and req.done
    assert req.output == ref
    # the prompt really went in chunk-by-chunk
    assert eng.prefill_dispatches == 5
    assert eng.prefill_tokens == 40


def test_overlong_prompt_rejected_not_truncated():
    """Regression: prompts beyond the lane capacity used to be silently
    truncated to ``max_prefill`` tokens; now they are refused loudly."""
    params = _params()
    eng = _engine(params, max_prefill=16)
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError, match="exceeds the lane prefill"):
        eng.admit(Request(uid=0, prompt=_prompt(rng, 17), max_new_tokens=4))
    # the lane is still free and the engine still serves
    ok = Request(uid=1, prompt=_prompt(rng, 16), max_new_tokens=4)
    done = serve(eng, [ok])
    assert len(done) == 1 and len(ok.output) == 4


def test_mixed_lengths_more_requests_than_slots():
    params = _params()
    rng = np.random.default_rng(6)
    lens = [3, 10, 17, 33, 40, 5]          # spans < chunk .. many chunks
    prompts = [_prompt(rng, n) for n in lens]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]

    eng = _engine(params, batch_slots=2, prefill_chunk=16)
    done = serve(eng, copy.deepcopy(reqs))
    assert sorted(r.uid for r in done) == list(range(6))
    by_uid = {r.uid: r for r in done}
    for i, p in enumerate(prompts):
        solo = _solo_reference(params, p, max_new=8)
        assert by_uid[i].output == solo, f"uid {i} (prompt len {lens[i]})"


def test_admission_overlaps_active_decode():
    """While a long prompt is being ingested chunk-by-chunk, an already
    decoding lane keeps emitting tokens — and its output is unchanged
    by the interleaved prefill traffic."""
    params = _params()
    rng = np.random.default_rng(7)
    a_prompt, b_prompt = _prompt(rng, 8), _prompt(rng, 40)
    solo_a = _solo_reference(params, a_prompt, max_new=20)
    solo_b = _solo_reference(params, b_prompt, max_new=8)

    eng = _engine(params, prefill_chunk=8, chunk_steps=2)
    a = Request(uid=0, prompt=a_prompt, max_new_tokens=20)
    b = Request(uid=1, prompt=b_prompt, max_new_tokens=8)
    eng.admit(a)
    eng.drain_prefill()                      # A decoding
    eng.admit(b)                             # B starts its 5-chunk ingest
    emitted_during_b_prefill = 0
    while eng.has_prefill_pending():
        n0 = len(a.output)
        eng.prefill_step()
        eng.step_chunk()                     # A advances mid-ingest
        emitted_during_b_prefill += len(a.output) - n0
    assert emitted_during_b_prefill > 0, \
        "decode stalled while a prompt was being ingested"
    while eng.has_active():
        eng.step_chunk()
    assert a.output == solo_a
    assert b.output == solo_b


# ---------------------------------------------------------------------------
# stopping conditions at admission
# ---------------------------------------------------------------------------
def test_max_new_tokens_one_never_occupies_a_decode_lane():
    params = _params()
    eng = _engine(params)
    rng = np.random.default_rng(8)
    req = Request(uid=0, prompt=_prompt(rng, 8), max_new_tokens=1)
    done = serve(eng, [req])
    assert len(done) == 1 and req.done
    assert len(req.output) == 1
    assert eng.dispatches == 0               # never entered decode
    assert not eng.has_active()


def test_immediate_eos_finishes_at_admission():
    params = _params()
    rng = np.random.default_rng(9)
    prompt = _prompt(rng, 8)
    # probe the greedy first token, then declare it the EOS id
    probe = Request(uid=0, prompt=prompt, max_new_tokens=1)
    serve(_engine(params), [probe])
    eos = probe.output[0]
    eng = _engine(params)
    req = Request(uid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    done = serve(eng, [req])
    assert len(done) == 1
    assert req.output == [eos]
    assert eng.dispatches == 0


# ---------------------------------------------------------------------------
# honest accounting
# ---------------------------------------------------------------------------
def test_emitted_token_accounting_is_true_counts():
    params = _params()
    rng = np.random.default_rng(10)
    reqs = [Request(uid=i, prompt=_prompt(rng, 8 + 4 * i),
                    max_new_tokens=3 + 2 * i) for i in range(4)]
    eng = _engine(params, batch_slots=2, prefill_chunk=16, chunk_steps=8)
    done = serve(eng, copy.deepcopy(reqs))
    emitted = sum(len(r.output) for r in done)
    assert eng.tokens_emitted == emitted
    assert eng.prefill_tokens == sum(8 + 4 * i for i in range(4))


def test_steps_executed_not_inflated_by_dead_chunk_tail():
    """One request, max_new=3, chunk of 8: the dispatch runs 8 scan
    steps but only 2 do work (tokens 2 and 3; token 1 came from
    prefill).  The old accounting charged all 8."""
    params = _params()
    rng = np.random.default_rng(11)
    req = Request(uid=0, prompt=_prompt(rng, 8), max_new_tokens=3)
    eng = _engine(params, chunk_steps=8)
    serve(eng, [req])
    assert len(req.output) == 3
    assert eng.dispatches == 1
    assert eng.steps_executed == 2
    assert eng.tokens_emitted == 3
