"""Run multi-device test-case bodies on ANY machine.

Mesh tests used to hide behind ``jax.device_count() < 2`` skips, which
meant they never ran in single-device CI.  :func:`run_case` executes a
named case from ``tests/mdev_cases.py``:

  * **in-process** when the running process already exposes enough
    devices (the multi-device CI leg sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before
    pytest starts, so jax initializes with 4 host devices);
  * otherwise **in a subprocess** whose environment forces host
    devices *before* jax initializes — the only point at which the
    device count can be chosen.

Either way the case body actually executes; there is no silent skip.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def run_case(name: str, ndev: int = 4, timeout: int = 1200) -> str:
    """Execute ``mdev_cases.<name>()`` under >= ``ndev`` devices.

    Returns "in-process" or "subprocess" (useful for debugging which
    path a CI leg exercised).  Raises AssertionError with the child's
    output on failure.
    """
    import jax
    if jax.device_count() >= ndev:
        import mdev_cases
        getattr(mdev_cases, name)()
        return "in-process"
    env = os.environ.copy()
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}"
                        ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    extra = [str(ROOT / "src"), str(ROOT), str(ROOT / "tests")]
    if env.get("PYTHONPATH"):
        extra.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(extra)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "mdev_cases.py"), name],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device case {name!r} failed in forced-{ndev}-device "
            f"subprocess (exit {proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n"
            f"--- stderr ---\n{proc.stderr}")
    return "subprocess"
