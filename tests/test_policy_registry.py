"""Registry + SparsityPolicy interface tests.

A new policy must be addable by dropping one file into
``core/policies/`` — the custom-policy test below does exactly that
(minus the file), registering a class and driving it through config
validation, cache sizing, and the decode hot path untouched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RaasConfig
from repro.core import paged_cache as pc
from repro.core.attention import decode_attend
from repro.core.policy_base import (SparsityPolicy, available_policies,
                                    get_policy, register_policy)


def test_builtins_registered():
    names = available_policies()
    for n in ("dense", "raas", "quest", "h2o", "streaming", "quest_raas"):
        assert n in names


def test_unknown_policy_rejected_everywhere():
    with pytest.raises(ValueError, match="unknown sparsity policy"):
        get_policy("nope")
    with pytest.raises(ValueError, match="unknown sparsity policy"):
        RaasConfig(policy="nope")


def test_cache_slots_trinity_axes():
    """The O(L)-vs-O(N) memory axis lives in SparsityPolicy.cache_slots."""
    cfg = RaasConfig(policy="raas", budget_tokens=32, page_size=4)
    long, short = 4096, 64
    # O(L): slots independent of sequence length
    for name in ("raas", "streaming", "h2o"):
        c = dataclasses.replace(cfg, policy=name)
        p = get_policy(name)
        assert p.cache_slots(c, long, 8) == p.cache_slots(c, short, 8)
    # O(N): slots scale with sequence length
    for name in ("dense", "quest"):
        c = dataclasses.replace(cfg, policy=name)
        p = get_policy(name)
        assert p.cache_slots(c, long, 8) > p.cache_slots(c, short, 8)
    # hybrid: prefill pages + decode budget
    c = dataclasses.replace(cfg, policy="quest_raas")
    p = get_policy("quest_raas")
    assert p.cache_slots(c, long, 16) == 16 // 4 + 32 // 4


def test_quest_raas_finalize_config_fills_hint():
    cfg = RaasConfig(policy="quest_raas", budget_tokens=32, page_size=4)
    out = get_policy("quest_raas").finalize_config(cfg, prefill_len=10)
    assert out.prefill_pages_hint == 3          # ceil(10 / 4)
    # an explicit hint is left alone
    explicit = dataclasses.replace(cfg, prefill_pages_hint=7)
    assert get_policy("quest_raas").finalize_config(
        explicit, prefill_len=10).prefill_pages_hint == 7


def test_custom_policy_one_class_plugs_in():
    """Register an out-of-tree policy and drive it end-to-end through
    config validation, cache sizing, and decode_attend."""

    @register_policy("tiny_window_test")
    class TinyWindow(SparsityPolicy):
        # sliding window of exactly budget_tokens, no sinks, no refresh
        def cache_slots(self, cfg, max_seq_len, prefill_len=0):
            return self.budget_slots(cfg, prefill_len)

    cfg = RaasConfig(policy="tiny_window_test", budget_tokens=8,
                     page_size=2)                 # validates via registry
    policy = get_policy("tiny_window_test")
    assert cfg.policy_obj is policy
    n_slots = policy.cache_slots(cfg, 64, 0)
    assert n_slots == 4
    spec = pc.CacheSpec(n_slots, 2, 1, 4, jnp.float32)
    cache = pc.init_cache(spec, 1)
    rng = np.random.default_rng(0)
    for step in range(12):
        q = jnp.asarray(rng.standard_normal((1, 2, 4)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, 4)), jnp.float32)
        cache, ctx, stats = decode_attend(cache, q, k, k, cfg,
                                          has_prefill=False)
        assert int(cache.tokens_cached()[0]) <= 8
        assert bool(jnp.isfinite(ctx).all())
    # frozen arrival-order priorities == sliding window: the retained
    # decode pages are the most recent ones
    pos = np.asarray(cache.page_pos[0])
    live = sorted(p for p, l in zip(pos, np.asarray(cache.page_len[0]))
                  if l > 0)
    assert live[-1] == 10                        # newest page present


def test_duplicate_registration_rejected():
    @register_policy("dup_test_policy")
    class DupA(SparsityPolicy):
        pass

    with pytest.raises(ValueError, match="already registered"):
        @register_policy("dup_test_policy")
        class DupB(SparsityPolicy):
            pass
