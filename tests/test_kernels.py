"""Per-kernel validation: Pallas (interpret) vs pure-jnp oracle.

Sweeps shapes (incl. GQA group sizes, ragged partial pages, index-table
selection variants, non-divisible block boundaries) and dtypes per the
deliverable spec.  The paged decode kernel is exercised through the
index-table contract of ``ops.paged_decode_attention``: page-major
cache storage ``[B, KV, S, P, hd]``, per-page live lengths, and an
optional duplicate-free ``sel_idx`` page table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_scan import flash_causal

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _ragged_page_len(B, S, P):
    """Random live-prefix lengths incl. empty and partial pages; page 0
    always full so every row has at least one live token."""
    plen = RNG.integers(0, P + 1, (B, S)).astype(np.int32)
    plen[:, 0] = P
    return jnp.asarray(plen)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# paged decode attention (zero-copy index-mapped kernel)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,S,P,hd", [
    (1, 4, 4, 4, 8, 32),     # MHA
    (2, 8, 2, 6, 16, 64),    # GQA x4
    (2, 8, 1, 5, 16, 128),   # MQA, odd page count
    (1, 16, 8, 12, 4, 16),   # small pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, KV, S, P, hd, dtype):
    q = _rand((B, H, hd), dtype)
    k = _rand((B, KV, S, P, hd), dtype)
    v = _rand((B, KV, S, P, hd), dtype)
    page_len = _ragged_page_len(B, S, P)
    scale = 1.0 / hd ** 0.5
    ctx0, pp0 = ops.paged_decode_attention(q, k, v, page_len, None, scale,
                                           impl="jnp")
    ctx1, pp1 = ops.paged_decode_attention(q, k, v, page_len, None, scale,
                                           impl="pallas_interpret")
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(ctx0, np.float32),
                               np.asarray(ctx1, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(pp0, pp1, atol=tol, rtol=tol)


@pytest.mark.parametrize("order", ["ascending", "descending", "shuffled"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sel_table(order, dtype):
    """Subset selection through the index table: oracle/pallas parity
    for every duplicate-free ordering, and the ordering itself must not
    change the attention output (softmax over the union of tokens)."""
    B, H, KV, S, P, hd = 2, 8, 2, 10, 8, 32
    n_sel = 5
    q = _rand((B, H, hd), dtype)
    k = _rand((B, KV, S, P, hd), dtype)
    v = _rand((B, KV, S, P, hd), dtype)
    page_len = _ragged_page_len(B, S, P)
    scale = 1.0 / hd ** 0.5

    base = np.stack([RNG.permutation(S)[:n_sel] for _ in range(B)])
    if order == "ascending":
        sel = np.sort(base, axis=1)
    elif order == "descending":
        sel = -np.sort(-base, axis=1)
    else:
        sel = base
    sel = jnp.asarray(sel.astype(np.int32))

    ctx0, pp0 = ops.paged_decode_attention(q, k, v, page_len, sel, scale,
                                           impl="jnp")
    ctx1, pp1 = ops.paged_decode_attention(q, k, v, page_len, sel, scale,
                                           impl="pallas_interpret")
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(ctx0, np.float32),
                               np.asarray(ctx1, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(pp0, pp1, atol=tol, rtol=tol)

    # order invariance: ctx identical to the ascending table's
    sel_sorted = jnp.sort(sel, axis=1)
    ctx_s, pp_s = ops.paged_decode_attention(q, k, v, page_len, sel_sorted,
                                             scale, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(ctx1, np.float32),
                               np.asarray(ctx_s, np.float32), atol=tol,
                               rtol=tol)
    # per-page probs follow the table's ordering
    inv = jnp.argsort(sel, axis=1)
    np.testing.assert_allclose(jnp.take_along_axis(pp1, inv, axis=1),
                               pp_s, atol=tol, rtol=tol)


def test_paged_attention_prob_mass_sums_to_heads():
    B, H, KV, S, P, hd = 2, 8, 4, 6, 16, 64
    q = _rand((B, H, hd), jnp.float32)
    k = _rand((B, KV, S, P, hd), jnp.float32)
    v = _rand((B, KV, S, P, hd), jnp.float32)
    page_len = jnp.full((B, S), P, jnp.int32)
    _, pp = ops.paged_decode_attention(q, k, v, page_len, None, 0.125,
                                       impl="jnp")
    np.testing.assert_allclose(pp.sum(-1), H, rtol=1e-5)
    # subset selection renormalizes over the selected tokens only
    sel = jnp.asarray([[0, 2], [1, 3]], jnp.int32)
    _, pp_sel = ops.paged_decode_attention(q, k, v, page_len, sel, 0.125,
                                           impl="pallas_interpret")
    np.testing.assert_allclose(pp_sel.sum(-1), H, rtol=1e-4)


def test_raw_pallas_entries_require_interpret():
    """Only ops.py chooses the execution mode: a direct kernel call
    without an explicit ``interpret`` must not silently interpret."""
    from repro.kernels.flash_prefill import flash_prefill_pallas
    from repro.kernels.page_score import page_score_pallas
    from repro.kernels.paged_attention import paged_decode_attention_pallas

    B, KV, G, S, P, hd = 1, 2, 2, 4, 8, 32
    qg = _rand((B, KV, G, hd), jnp.float32)
    kp = _rand((B, KV, S, P, hd), jnp.float32)
    sel = jnp.zeros((B, S), jnp.int32)
    with pytest.raises(TypeError):
        paged_decode_attention_pallas(sel, sel, qg, kp, kp, scale=1.0)
    rep = _rand((B, KV, S, hd), jnp.float32)
    with pytest.raises(TypeError):
        page_score_pallas(qg, rep, rep, jnp.ones((B, S)), scale=1.0,
                          block_pages=S)
    qf = _rand((B, 8, KV * G, hd), jnp.float32)
    kf = _rand((B, 8, KV, hd), jnp.float32)
    info = jnp.zeros((2, B), jnp.int32)
    with pytest.raises(TypeError):
        flash_prefill_pallas(info, qf.transpose(0, 2, 1, 3),
                             kf.transpose(0, 2, 1, 3),
                             kf.transpose(0, 2, 1, 3), scale=1.0)


# ---------------------------------------------------------------------------
# page score
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 8, 32), (2, 8, 2, 6, 64), (3, 8, 1, 10, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_page_score(B, H, KV, S, hd, dtype):
    q = _rand((B, H, hd), dtype)
    rmin = _rand((B, KV, S, hd), jnp.float32)
    rmax = rmin + jnp.abs(_rand((B, KV, S, hd), jnp.float32))
    mask = jnp.asarray(RNG.random((B, S)) > 0.3)
    s0 = ops.page_score(q, rmin, rmax, mask, 0.125, impl="jnp")
    s1 = ops.page_score(q, rmin, rmax, mask, 0.125,
                        impl="pallas_interpret", block_pages=2)
    valid_err = jnp.abs(jnp.where(mask, s0 - s1, 0.0)).max()
    assert float(valid_err) < TOL[dtype]


def test_page_score_is_upper_bound():
    """Quest bound: page score >= every in-page token's true logit."""
    B, H, KV, S, P, hd = 1, 4, 2, 4, 8, 32
    q = _rand((B, H, hd), jnp.float32)
    k = _rand((B, KV, S, P, hd), jnp.float32)
    rmin = k.min(axis=3)
    rmax = k.max(axis=3)
    mask = jnp.ones((B, S), bool)
    score = ops.page_score(q, rmin, rmax, mask, 1.0, impl="jnp")
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgd,bkspd->bkgsp", qg, k)
    true_max = logits.max(axis=(1, 2, 4))     # [B, S]
    assert bool(jnp.all(score >= true_max - 1e-5))


# ---------------------------------------------------------------------------
# flash prefill (pallas) & flash scan (jnp custom-vjp)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd,off", [
    (1, 32, 32, 4, 4, 32, 0),
    (2, 24, 40, 8, 2, 64, 16),   # chunked-prefill offset
    (1, 17, 33, 6, 3, 16, 0),    # non-divisible by blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_pallas(B, Sq, Skv, H, KV, hd, off, dtype):
    q = _rand((B, Sq, H, hd), dtype)
    k = _rand((B, Skv, KV, hd), dtype)
    v = _rand((B, Skv, KV, hd), dtype)
    scale = 1.0 / hd ** 0.5
    ref = ops.flash_prefill(q, k, v, scale, q_offset=off,
                            impl="jnp_naive")
    got = ops.flash_prefill(q, k, v, scale, q_offset=off,
                            impl="pallas_interpret", block_q=16,
                            block_k=16)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_scan_matches_naive_and_grads():
    B, Sq, H, KV, hd = 2, 40, 6, 3, 16
    q = _rand((B, Sq, H, hd), jnp.float32)
    k = _rand((B, Sq, KV, hd), jnp.float32)
    v = _rand((B, Sq, KV, hd), jnp.float32)
    ref = ops.flash_prefill(q, k, v, 0.25, impl="jnp_naive")
    got = flash_causal(q, k, v, 0.25, 0, 16)
    np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)

    def loss_naive(q, k, v):
        return (ops.flash_prefill(q, k, v, 0.25, impl="jnp_naive") ** 2).sum()

    def loss_flash(q, k, v):
        return (flash_causal(q, k, v, 0.25, 0, 16) ** 2).sum()

    g0 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)
