"""Fixture: fancy-index gather on a PagedCache KV array outside
kernels/ — exactly one finding."""


def gather(k_pages, sel):
    return k_pages[sel]  # FIRE
