"""Fixture: a justified marker that suppresses nothing — one
unused-suppression finding, so stale exemptions cannot linger."""


def clean(x):
    # analysis: allow=paged-gather-outside-kernels -- fixture: nothing to suppress here
    return x + 1
