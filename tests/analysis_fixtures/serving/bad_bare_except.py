"""Fixture: bare ``except:`` in serving code.

A dispatch failure caught by a bare except never reaches a terminal
request status — the lint must flag it.  Exactly one finding.
"""


def fn():
    raise RuntimeError("boom")


def drive():
    try:
        fn()
    except:  # FIRE
        return None
    return 1
