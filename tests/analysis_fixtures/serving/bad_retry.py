"""Fixture: unbounded retry loop in serving code.

``while True:`` wrapped around a try/except retry is a livelock when
the fault is permanent — the lint must flag it.  Exactly one finding.
"""


def fn():
    raise ValueError("transient?")


def drive():
    while True:  # FIRE
        try:
            return fn()
        except ValueError:
            continue
