"""Fixture: raw refcount mutation outside the pool modules — exactly
one finding (claims move only via page_pool lane transitions)."""


def steal_claim(cache, lane, slot):
    return cache.refcount.at[lane, slot].add(1)  # FIRE
