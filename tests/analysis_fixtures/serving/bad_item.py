"""Fixture: .item() anywhere in serving code — exactly one finding
(blocking scalar round-trip; loops are not required)."""


def peek(x):
    return x.item()  # FIRE
