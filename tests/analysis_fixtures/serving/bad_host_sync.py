"""Fixture: per-iteration host sync inside a serving loop — exactly
one finding (the same float() outside the loop would be clean)."""
import jax.numpy as jnp


def drain(chunks):
    total = 0.0
    for c in chunks:
        total += float(jnp.sum(c))  # FIRE
    return total
