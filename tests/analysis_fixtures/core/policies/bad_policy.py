"""Fixture: a policy file importing engine internals — exactly one
finding (policies import policy_base and siblings only)."""
from repro.serving.engine import Engine  # FIRE


class BadPolicy:
    engine_cls = Engine
