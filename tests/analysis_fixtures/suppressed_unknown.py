"""Fixture: an allow marker naming a rule that does not exist — one
unknown-suppression finding."""


def clean(x):
    return x + 1  # analysis: allow=not-a-real-rule -- fixture: typo'd rule id
