"""Fixture: a justified inline allow marker — zero findings."""


def gather(k_pages, sel):
    return k_pages[sel]  # analysis: allow=paged-gather-outside-kernels -- fixture: justified marker on the offending line
