"""Fixture: pallas_call inside kernels/ (location rule silent) but
without an explicit interpret= kwarg — exactly one finding."""
from jax.experimental import pallas as pl


def run(kernel, x):
    return pl.pallas_call(kernel, out_shape=x)(x)  # FIRE
