"""Fixture: an allow marker without a justification — the original
finding stays AND a bare-suppression finding is added."""


def gather(k_pages, sel):
    return k_pages[sel]  # analysis: allow=paged-gather-outside-kernels
