"""Fixture: raw pallas_call outside kernels/.  interpret= is threaded
so only the location rule fires — exactly one finding."""
from jax.experimental import pallas as pl


def run(kernel, x):
    return pl.pallas_call(kernel, out_shape=x, interpret=False)(x)  # FIRE
