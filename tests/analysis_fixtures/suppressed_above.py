"""Fixture: a justified standalone marker on the line above — zero
findings."""


def gather(k_pages, sel):
    # analysis: allow=paged-gather-outside-kernels -- fixture: marker on the line above
    return k_pages[sel]
