"""The static-analysis suite, tested against itself.

Three layers of coverage:

1.  **Lint fixtures** — one tiny file per rule under
    ``tests/analysis_fixtures/`` (the fixture tree mimics the package
    layout, since rules are path-scoped).  Each violation fixture must
    produce *exactly one* finding, with the right rule id and the right
    line (the ``# FIRE`` marker); the suppression fixtures pin the
    allow-marker contract (justified suppresses, bare/unknown/unused
    are themselves findings).
2.  **HLO passes** — synthetic HLO snippets per pass, plus donation
    headers from really-compiled jitted functions.
3.  **The repo itself** — ``lint_tree`` over ``src/repro`` is clean,
    and the serving engine's jitted dispatches pass the full audit with
    the KV cache donated (alias bytes >= one full cache).
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import engine_audit, hlo as H, lint, run as cli
from repro.analysis.findings import Finding

FIXTURES = Path(__file__).parent / "analysis_fixtures"
SRC_ROOT = Path(__file__).parents[1] / "src" / "repro"


def _fire_line(path: Path) -> int:
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if line.rstrip().endswith("# FIRE"):
            return i
    raise AssertionError(f"no # FIRE marker in {path}")


# ---------------------------------------------------------------------------
# lint: violation fixtures — exactly one finding, right rule, right line
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rel,rule", [
    ("bad_pallas_site.py", "pallas-call-outside-kernels"),
    ("kernels/bad_interpret.py", "pallas-missing-interpret"),
    ("serving/bad_host_sync.py", "host-sync-in-dispatch-loop"),
    ("serving/bad_item.py", "host-sync-in-dispatch-loop"),
    ("bad_paged_gather.py", "paged-gather-outside-kernels"),
    ("core/policies/bad_policy.py", "policy-imports"),
    ("serving/bad_refcount.py", "pool-refcount-outside-pool"),
    ("serving/bad_bare_except.py", "no-bare-except-in-serving"),
    ("serving/bad_retry.py", "no-unbounded-retry"),
])
def test_violation_fixture_fires_exactly_once(rel, rule):
    path = FIXTURES / rel
    findings = lint.lint_file(path, FIXTURES)
    assert len(findings) == 1, findings
    f = findings[0]
    assert f.rule == rule
    assert f.line == _fire_line(path)
    assert f.path == rel


# ---------------------------------------------------------------------------
# lint: suppression contract
# ---------------------------------------------------------------------------
def test_justified_suppression_silences():
    for rel in ("suppressed_ok.py", "suppressed_above.py"):
        assert lint.lint_file(FIXTURES / rel, FIXTURES) == [], rel


def test_bare_suppression_keeps_finding_and_reports_marker():
    rules = {f.rule for f in
             lint.lint_file(FIXTURES / "suppressed_bare.py", FIXTURES)}
    assert rules == {"paged-gather-outside-kernels", "bare-suppression"}


def test_unused_and_unknown_suppressions_are_findings():
    (f,) = lint.lint_file(FIXTURES / "suppressed_unused.py", FIXTURES)
    assert f.rule == "unused-suppression"
    (f,) = lint.lint_file(FIXTURES / "suppressed_unknown.py", FIXTURES)
    assert f.rule == "unknown-suppression"


def test_repo_lint_is_clean():
    """The shipped tree carries no violations and no stale markers."""
    assert lint.lint_tree(SRC_ROOT) == []


# ---------------------------------------------------------------------------
# HLO passes: synthetic programs
# ---------------------------------------------------------------------------
def test_kv_copy_ops_threshold_and_span():
    txt = ("  %transpose.9 = f32[4,2,16]{2,1,0} transpose(f32[4,16,2]"
           "{2,1,0} %p0), dimensions={0,2,1}\n"
           "  %gather.1 = s32[4096]{0} gather(s32[8192]{0} %p1, "
           "s32[4096,1]{1,0} %idx)\n")
    hits = H.kv_copy_ops(txt, 128)
    assert len(hits) == 1                 # int gather is index traffic
    op, dims, line_no, span = hits[0]
    assert (op, dims, line_no) == ("transpose", (4, 2, 16), 1)
    assert "transpose.9" in span
    assert H.kv_copy_ops(txt, 129) == []


def test_host_transfer_pass():
    txt = ("  %of = token[] outfeed(f32[8]{0} %x, token[] %tok)\n"
           "  %cc = f32[2]{0} custom-call(f32[2]{0} %y), "
           'custom_call_target="MoveToHost"\n'
           "  %p0 = f32[128,8]{1,0:S(5)} parameter(0)\n"
           "  %ad = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)\n")
    found = H.host_transfer_findings(txt, label="t")
    assert [f.line for f in found] == [1, 2, 3]
    assert {f.rule for f in found} == {"host-transfer"}
    assert H.host_transfer_findings("%a = f32[2]{0:S(0)} parameter(0)") \
        == []


def test_collective_budget_pass():
    txt = ("  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), "
           "replica_groups={{0,1}}, to_apply=%sum\n")
    (f,) = H.collective_findings(txt, max_bytes=0.0, label="t")
    assert f.rule == "collective-traffic"
    assert "4096" in f.message           # 1024 * 4B * 2(g-1)/g
    assert H.collective_findings(txt, max_bytes=1e9) == []
    assert H.collective_findings("", max_bytes=0.0) == []


def test_jit_cache_guard():
    ok = H.jit_cache_findings(prefill_traces=3, prefill_pages=4,
                              decode_traces=1, distinct_decode_steps=1)
    assert ok == []
    bad = H.jit_cache_findings(prefill_traces=9, prefill_pages=4,
                               decode_traces=3, distinct_decode_steps=1)
    assert [f.rule for f in bad] == ["jit-cache-growth"] * 2


# ---------------------------------------------------------------------------
# donation auditor: headers from really-compiled programs
# ---------------------------------------------------------------------------
def _compile_add(donate):
    kw = {"donate_argnums": (0,)} if donate else {}
    f = jax.jit(lambda x, y: x + y, **kw)
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    return f.lower(s, s).compile()


def test_donation_parse_and_findings():
    donated_txt = _compile_add(donate=True).as_text()
    plain_txt = _compile_add(donate=False).as_text()

    assert H.donated_params(donated_txt) == {0: 0}
    assert H.donated_params(plain_txt) == {}

    params, outs = H.entry_params_and_outputs(plain_txt)
    assert params == ["f32[256,256]", "f32[256,256]"]
    assert outs == ["f32[256,256]"]

    assert H.donation_findings(donated_txt, min_bytes=1) == []
    found = H.donation_findings(plain_txt, min_bytes=1, label="add")
    assert len(found) == 1               # one free output to alias onto
    assert found[0].rule == "undonated-buffer"
    # below the size floor, or explicitly allowed: silent
    assert H.donation_findings(plain_txt, min_bytes=1 << 30) == []
    assert H.donation_findings(
        plain_txt, min_bytes=1,
        allow={"f32[256,256]": "test exemption"}) == []


def test_donation_report_measures_alias():
    rep_d = H.donation_report(_compile_add(donate=True))
    rep_p = H.donation_report(_compile_add(donate=False))
    buf = 256 * 256 * 4
    assert rep_d["alias_bytes"] >= buf
    assert rep_p["alias_bytes"] == 0
    assert rep_d["peak_live_bytes"] + buf \
        == rep_d["peak_live_bytes_undonated"]


# ---------------------------------------------------------------------------
# the real engine: full audit is clean, cache donation is in effect
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_engine():
    from repro.config import ModelConfig, RaasConfig
    from repro.models import model as M
    from repro.serving.engine import Engine
    cfg = ModelConfig(name="audit-tiny", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    raas = RaasConfig(policy="quest", budget_tokens=64, page_size=16,
                      quest_topk_pages=2)
    return Engine(params, cfg, raas, batch_slots=2, max_seq=128,
                  max_prefill=32, prefill_chunk=16, chunk_steps=2)


def test_engine_audit_no_findings_and_cache_donated(tiny_engine):
    findings, report = engine_audit.audit_engine(tiny_engine)
    assert findings == [], "\n".join(f.format() for f in findings)
    assert set(report) == set(engine_audit.DISPATCHES)
    k = tiny_engine.cache.per_pos[0].attn.k_pages
    cache_kv_bytes = 2 * k.size * k.dtype.itemsize       # K + V pages
    for name, rep in report.items():
        assert rep["alias_bytes"] >= cache_kv_bytes, (name, rep)
        assert rep["peak_live_bytes"] < rep["peak_live_bytes_undonated"]


def test_engine_dispatch_headers_alias_the_cache(tiny_engine):
    """Every chunked dispatch donates its cache argument: reset arg 0,
    prefill/decode arg 1 (plus the cache's other leaves)."""
    lowered = engine_audit.dispatch_lowerings(tiny_engine)
    n_cache_leaves = len(jax.tree.leaves(tiny_engine.cache))
    for name, low in lowered.items():
        donated = H.donated_params(low.compile().as_text())
        assert len(donated) == n_cache_leaves, (name, donated)


def test_audit_rejects_fallback_engine():
    class Fake:
        chunked_prefill = False
    with pytest.raises(ValueError, match="one-shot prefill fallback"):
        engine_audit.dispatch_lowerings(Fake())


def test_full_cache_elems_matches_layout(tiny_engine):
    k = tiny_engine.cache.per_pos[0].attn.k_pages
    L, B, KV, S, P, hd = k.shape
    assert engine_audit.full_cache_elems(tiny_engine) \
        == B * KV * S * P * hd


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_lint_only_passes_on_repo(capsys):
    assert cli.main(["--strict", "--skip-hlo"]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_cli_strict_fails_on_fixture_tree(capsys):
    rc = cli.main(["--strict", "--skip-hlo", "--root", str(FIXTURES)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "[policy-imports]" in out and "[bare-suppression]" in out
