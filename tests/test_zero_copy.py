"""Zero-copy paged decode: policy parity + HLO regression tests.

Two families of guarantees for the index-mapped kernel rewrite:

1.  **Parity** — for every registered policy, a full decode trace
    (prefill ingest + ragged partial pages + eviction + selection)
    produces the same contexts and the same cache state on the jnp
    oracle and the Pallas interpret backend.  This is end-to-end: it
    exercises page_score, the index-table handoff, the paged attention
    kernel, and the policies' priority dynamics together.

2.  **Zero-copy regression** — the jitted decode-step HLO of the
    Pallas path must contain no transpose or gather materializing KV
    bytes at or above the size of a gathered page copy
    ``[B, nSel, KV, P, hd]``: page selection must reach the kernel as
    indices, never as a copied tensor.  The jnp oracle path is allowed
    its O(nSel) gather but must never transpose or gather the *full*
    O(S) cache — per-step traffic stays bounded by the selection size
    L, not the slot count S.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core.attention import decode_attend
from repro.core.policy_base import available_policies, get_policy
from repro.kernels import ops

P, KV, HD, B, H = 4, 2, 16, 1, 4
PREFILL = 6
N_DECODE = 10


def _cfg(policy: str) -> RaasConfig:
    return RaasConfig(policy=policy, budget_tokens=4 * P, page_size=P,
                      quest_topk_pages=3, h2o_recent=4,
                      prefill_pages_hint=-(-PREFILL // P))


def _trace(policy: str, impl: str):
    """Run a decode trace; return (ctx list, final cache)."""
    cfg = _cfg(policy)
    n_slots = get_policy(policy).cache_slots(cfg, PREFILL + N_DECODE + 1,
                                             PREFILL)
    spec = pc.CacheSpec(n_slots, P, KV, HD, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(7)
    k = jnp.asarray(rng.standard_normal((B, PREFILL, KV, HD)), jnp.float32)
    cache = pc.ingest_prefill(cache, k, k, jnp.full((B,), PREFILL))
    ctxs = []
    for _ in range(N_DECODE):
        q = jnp.asarray(rng.standard_normal((B, H, HD)), jnp.float32)
        kn = jnp.asarray(rng.standard_normal((B, KV, HD)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((B, KV, HD)), jnp.float32)
        cache, ctx, _ = decode_attend(cache, q, kn, vn, cfg, impl=impl)
        ctxs.append(ctx)
    return ctxs, cache


@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_policy_parity_oracle_vs_pallas_interpret(policy):
    """All registered policies: identical decode traces on both
    backends, including ragged partial pages and evictions."""
    ctx_j, cache_j = _trace(policy, "jnp")
    ctx_p, cache_p = _trace(policy, "pallas_interpret")
    for step, (a, b) in enumerate(zip(ctx_j, ctx_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"{policy} ctx diverged @ {step}")
    for name, a, b in zip(cache_j._fields, cache_j, cache_p):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"{policy} cache.{name} diverged")


# ---------------------------------------------------------------------------
# HLO regression: selection is indices-only, no KV-sized copies.
# The detector is the shared repro.analysis.hlo pass (same one the
# `python -m repro.analysis.run` CLI and CI leg run over the engine).
# ---------------------------------------------------------------------------
from repro.analysis.hlo import kv_copy_ops as _copy_ops_at_least  # noqa: E402


def _compiled_decode_step(impl: str, n_slots: int, policy: str = "quest"):
    cfg = RaasConfig(policy=policy, budget_tokens=4 * P, page_size=P,
                     quest_topk_pages=3)
    spec = pc.CacheSpec(n_slots, P, KV, HD, jnp.float32)
    cache = pc.init_cache(spec, B)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, PREFILL, KV, HD)), jnp.float32)
    cache = pc.ingest_prefill(cache, k, k, jnp.full((B,), PREFILL))
    q = jnp.asarray(rng.standard_normal((B, H, HD)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((B, KV, HD)), jnp.float32)
    fn = jax.jit(lambda c, q, kn: decode_attend(c, q, kn, kn, cfg,
                                                impl=impl))
    return fn.lower(cache, q, kn).compile()


def test_pallas_decode_step_hlo_has_no_kv_copy():
    """The kernel path must resolve pages via the scalar-prefetched
    index table: no float transpose/gather at or above the size of a
    gathered page copy may appear anywhere in the optimized HLO."""
    n_slots = 16
    comp = _compiled_decode_step("pallas_interpret", n_slots)
    n_sel = 3 + 1                    # quest top-k (+1 headroom)
    copy_elems = B * n_sel * KV * P * HD
    bad = _copy_ops_at_least(comp.as_text(), copy_elems)
    assert not bad, f"KV-sized copies in pallas decode step: {bad}"


def test_oracle_decode_step_hlo_has_no_full_cache_copy():
    """The jnp oracle may gather the O(L) selection but must never
    transpose/gather the full O(S) cache."""
    n_slots = 16
    comp = _compiled_decode_step("jnp", n_slots)
    full_cache_elems = B * KV * n_slots * P * HD
    bad = _copy_ops_at_least(comp.as_text(), full_cache_elems)
    assert not bad, f"full-cache copies in oracle decode step: {bad}"


def test_oracle_attention_bytes_slope_is_one_cache_read():
    """Growing the slot count S at a fixed selection size must cost the
    oracle attention op at most ~one cache read per added slot (XLA's
    cost model charges a gather its full operand).  The old
    reshape+transpose-then-gather pipeline paid >= 3 cache sweeps per
    slot (transpose read + write + downstream read); a relapse trips
    this slope bound."""
    def attn_bytes(S):
        n_sel = 4
        q = jnp.zeros((B, H, HD))
        kp = jnp.zeros((B, KV, S, P, HD))
        plen = jnp.full((B, S), P, jnp.int32)
        sel = jnp.zeros((B, n_sel), jnp.int32)
        fn = jax.jit(lambda q, kp, vp, plen, sel: ops.paged_decode_attention(
            q, kp, vp, plen, sel, 0.25, impl="jnp"))
        ca = fn.lower(q, kp, kp, plen, sel).compile().cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return ca["bytes accessed"]

    small, big = 16, 64
    slope = (attn_bytes(big) - attn_bytes(small)) / (big - small)
    cache_bytes_per_slot = 2 * B * KV * P * HD * 4          # K+V, f32
    assert slope <= 2 * cache_bytes_per_slot, (
        f"oracle attention bytes grow {slope:.0f} B/slot for "
        f"{cache_bytes_per_slot} B/slot of cache — an O(S) copy is back "
        f"on the attention path")


def test_analytic_kernel_cost_is_o_l():
    """The kernel's exact HBM traffic is a function of the selection
    size only — independent of the slot count S by construction."""
    from repro.kernels.ops import paged_decode_attention_cost
    c1 = paged_decode_attention_cost(B=1, KV=2, G=2, hd=64, P=16, n_sel=8)
    c2 = paged_decode_attention_cost(B=1, KV=2, G=2, hd=64, P=16, n_sel=16)
    assert c2["bytes_accessed"] < 2.1 * c1["bytes_accessed"]
    kv_bytes = 2 * 2 * 8 * 16 * 64 * 4
    assert c1["bytes_accessed"] >= kv_bytes        # dominated by K+V pages
    assert c1["bytes_accessed"] < 1.2 * kv_bytes   # ... and nothing O(S)
