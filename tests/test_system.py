"""End-to-end system tests: training convergence, the serving engine,
checkpointing round-trips, the data pipeline, and the optimizer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RaasConfig, RunConfig
from repro.data.pipeline import (DataConfig, batches, make_example,
                                 prompt_of, specials, verify_answer)
from repro.launch.train import make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_verifiable():
    dc = DataConfig(vocab_size=128, seq_len=128)
    a1, m1, ans1 = make_example(dc, 7)
    a2, m2, ans2 = make_example(dc, 7)
    np.testing.assert_array_equal(a1, a2)
    assert ans1 == ans2
    # the gold chain itself verifies
    assert verify_answer(dc, 7, a1)
    # a corrupted answer fails
    sp = specials(dc)
    bad = a1.copy()
    idx = int(np.argmax(bad == sp["A"]))
    bad[idx + 1] = (bad[idx + 1] + 1) % dc.modulus
    assert not verify_answer(dc, 7, bad)


def test_data_batches_and_prompt():
    dc = DataConfig(vocab_size=128, seq_len=64, chain_steps=8)
    b = next(batches(dc, 4))
    assert b["tokens"].shape == (4, 64)
    assert b["loss_mask"].shape == (4, 64)
    prompt, n = prompt_of(dc, 0)
    assert n == len(prompt) and n <= 16
    assert (b["loss_mask"].sum(1) > 0).all()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 5.0])}
    opt = adamw.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt = adamw.update(params, g, opt, lr=jnp.float32(0.1),
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    gn = float(jnp.sqrt((clipped["a"] ** 2).sum()))
    assert abs(gn - 1.0) < 1e-4


def test_cosine_schedule_shape():
    lr0 = adamw.cosine_schedule(jnp.array(0), 1.0, 10, 100)
    lr_w = adamw.cosine_schedule(jnp.array(10), 1.0, 10, 100)
    lr_end = adamw.cosine_schedule(jnp.array(100), 1.0, 10, 100)
    assert 0.0 < float(lr0) <= 0.2   # warmup starts non-zero
    assert abs(float(lr_w) - 1.0) < 1e-5
    assert float(lr_end) == pytest.approx(0.1, abs=1e-5)


# ---------------------------------------------------------------------------
# training end-to-end: loss must drop on the synthetic CoT corpus
# ---------------------------------------------------------------------------
def test_training_loss_decreases():
    dc = DataConfig(vocab_size=TINY.vocab_size, seq_len=64,
                    chain_steps=8)
    run = RunConfig(arch="tiny", lr=1e-2, total_steps=30, warmup_steps=3)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(TINY, run))
    it = batches(dc, 8)
    losses = []
    for i in range(30):
        b = next(it)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "loss_mask": jnp.asarray(b["loss_mask"])}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
def test_engine_continuous_batching():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=2, max_seq=96,
                 max_prefill=16)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new_tokens=12) for i in range(5)]
    done = serve(eng, reqs)
    assert len(done) == 5
    for r in done:
        assert r.done and 1 <= len(r.output) <= 12
    # 5 requests through 2 lanes => engine reused lanes
    assert eng.steps_executed >= 12


def test_engine_raas_memory_constant():
    """Paper Fig. 7: RaaS KV bytes are O(L), independent of decode len."""
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    raas = RaasConfig(policy="raas", budget_tokens=32, page_size=4)
    eng_short = Engine(params, TINY, raas, batch_slots=1, max_seq=64,
                       max_prefill=8)
    eng_long = Engine(params, TINY, raas, batch_slots=1, max_seq=4096,
                      max_prefill=8)
    # O(L) policy: cache allocation does NOT scale with max_seq
    assert eng_short.kv_cache_bytes() == eng_long.kv_cache_bytes()
    dense = RaasConfig(policy="dense", budget_tokens=32, page_size=4)
    eng_dense = Engine(params, TINY, dense, batch_slots=1, max_seq=4096,
                       max_prefill=8)
    assert eng_dense.kv_cache_bytes() > 10 * eng_long.kv_cache_bytes()


def test_engine_eos_stops_early():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    raas = RaasConfig(policy="dense", budget_tokens=64, page_size=4)
    eng = Engine(params, TINY, raas, batch_slots=1, max_seq=64,
                 max_prefill=16)
    prompt = np.arange(8, dtype=np.int32)
    probe = Request(uid=0, prompt=prompt, max_new_tokens=5)
    serve(eng, [probe])
    eos = probe.output[1] if len(probe.output) > 1 else probe.output[0]
    eng2 = Engine(params, TINY, raas, batch_slots=1, max_seq=64,
                  max_prefill=16)
    r = Request(uid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    serve(eng2, [r])
    assert len(r.output) < 50


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    opt = adamw.init(params)
    path = os.path.join(tmp_path, "1.msgpack")
    ckpt.save(path, {"params": params, "opt": opt})
    like = jax.eval_shape(lambda: {"params": params, "opt": opt})
    restored = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import ckpt
    path = os.path.join(tmp_path, "1.msgpack")
    ckpt.save(path, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(path, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})
