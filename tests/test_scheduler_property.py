"""Property tests for the continuous-batching scheduler loop.

For ARBITRARY admission sequences of (prompt_len, max_new_tokens,
eos?) the loop must:

  * admit strictly FIFO (request i never admitted after request j > i),
  * never exceed lane capacity (``Engine.admit`` raises on a full
    engine — any such raise fails the property),
  * complete every request exactly once and leave the engine idle,
  * account ``tokens_emitted`` EXACTLY: the engine's device-side
    emitted count equals the sum of output lengths over completions,
  * honor per-request budgets: 1 <= len(output) <= max_new_tokens
    (0 outputs exactly when max_new_tokens < 1).

One engine instance is shared across examples (it returns to all-lanes
-FREE after each serve, which the property itself asserts), so the
jitted chunk functions compile once, not once per hypothesis example.
A deterministic fallback covers the same invariants when hypothesis is
not installed.
"""
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency: the property tests below
    # skip cleanly when it is absent so collection never breaks.
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        def deco(fn):
            @_SKIP
            @functools.wraps(fn)
            def stub(*args, **kwargs):
                raise AssertionError("unreachable: test is skipped")
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

import jax

from repro.config import ModelConfig, RaasConfig
from repro.models import model as M
from repro.serving import resilience as R
from repro.serving.engine import FREE, Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)
MAX_PREFILL = 32
EOS = 7

_ENGINE = None


def _engine() -> Engine:
    global _ENGINE
    if _ENGINE is None:
        params = M.init_params(jax.random.PRNGKey(0), TINY)
        raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
        _ENGINE = Engine(params, TINY, raas, batch_slots=3, max_seq=64,
                         max_prefill=MAX_PREFILL, prefill_chunk=8,
                         chunk_steps=4)
    return _ENGINE


def _check_invariants(reqs_spec):
    """Serve the sequence and assert every scheduler invariant."""
    eng = _engine()
    assert all(p == FREE for p in eng.phase), "engine not idle at entry"
    rng = np.random.default_rng(1234)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        size=plen).astype(np.int32),
                    max_new_tokens=max_new,
                    eos_id=EOS if use_eos else None)
            for i, (plen, max_new, use_eos) in enumerate(reqs_spec)]

    admitted = []
    orig_admit = eng.admit

    def recording_admit(req):
        admitted.append(req.uid)
        orig_admit(req)

    emitted_before = eng.tokens_emitted
    eng.admit = recording_admit
    try:
        done = serve(eng, reqs)
    finally:
        del eng.admit                    # restore the bound method

    # FIFO admission: uids are assigned in submission order
    assert admitted == sorted(admitted) == list(range(len(reqs)))
    # every request completes exactly once
    assert sorted(r.uid for r in done) == list(range(len(reqs)))
    assert all(r.done for r in done)
    # budgets honored; at least one token whenever the budget allows
    for r in done:
        if r.max_new_tokens < 1:
            assert r.output == [], r.uid
        else:
            assert 1 <= len(r.output) <= r.max_new_tokens, r.uid
            if r.eos_id is not None and EOS in r.output:
                # stop AT the eos token, never after it
                assert r.output.index(EOS) == len(r.output) - 1, r.output
    # exact accounting: device-side emitted mask == host-side outputs
    assert eng.tokens_emitted - emitted_before \
        == sum(len(r.output) for r in done)
    # a fault-free serve ends every request OK — never a silent None
    assert all(r.status == R.OK for r in done)
    # the engine drained: no lane leaked, no request stranded
    assert all(p == FREE for p in eng.phase)
    assert not eng.has_active() and not eng.has_prefill_pending()
    assert all(r is None for r in eng.slot_req)
    # ... and no pool claim leaked either (parked prefixes only)
    eng.audit_refcounts()


def _check_fault_invariants(reqs_spec, seed, preempt_after):
    """Serve under a seeded FaultPlan (+ optional preemption) and
    assert the resilience contract: every request reaches exactly one
    terminal status, token accounting stays exact including discarded
    tokens, and the drained engine leaks neither lanes nor pool
    claims.  FIFO recording is deliberately not asserted here — lane
    loss legitimately re-admits a request out of band."""
    eng = _engine()
    assert all(p == FREE for p in eng.phase), "engine not idle at entry"
    rng = np.random.default_rng(4321)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        size=plen).astype(np.int32),
                    max_new_tokens=max_new,
                    eos_id=EOS if use_eos else None)
            for i, (plen, max_new, use_eos) in enumerate(reqs_spec)]
    plan = R.FaultPlan(seed=seed, p_dispatch_error=0.25, p_nan=0.15,
                       p_lane_loss=0.1, p_admission_race=0.25,
                       max_faults=10)
    e0, d0 = eng.tokens_emitted, eng.tokens_discarded
    eng.set_faults(plan)
    try:
        done = serve(eng, reqs, preempt_after=preempt_after)
    finally:
        eng.set_faults(None)
    # every request terminates exactly once, with a terminal status
    assert sorted(r.uid for r in done) == list(range(len(reqs)))
    for r in done:
        assert r.done and r.status in R.TERMINAL_STATUSES, \
            (r.uid, r.status)
    # exact accounting even under faults: emitted == surviving + discarded
    assert eng.tokens_emitted - e0 \
        == sum(len(r.output) for r in done) + (eng.tokens_discarded - d0)
    assert all(p == FREE for p in eng.phase)
    assert all(r is None for r in eng.slot_req)
    eng.audit_refcounts()


@settings(max_examples=12, deadline=None)
@given(st.lists(
    st.tuples(st.integers(min_value=1, max_value=MAX_PREFILL),
              st.integers(min_value=0, max_value=10),
              st.booleans()),
    min_size=1, max_size=10))
def test_scheduler_invariants_property(reqs_spec):
    _check_invariants(reqs_spec)


@settings(max_examples=8, deadline=None)
@given(st.lists(
    st.tuples(st.integers(min_value=1, max_value=MAX_PREFILL),
              st.integers(min_value=0, max_value=10),
              st.booleans()),
    min_size=1, max_size=8),
    st.integers(min_value=0, max_value=2 ** 16),
    st.sampled_from([0, 2]))
def test_scheduler_fault_invariants_property(reqs_spec, seed,
                                             preempt_after):
    _check_fault_invariants(reqs_spec, seed, preempt_after)


def test_scheduler_invariants_deterministic():
    """Fixed sequence exercising the same invariants (runs even when
    hypothesis is absent): capacity pressure (8 requests, 3 lanes),
    multi-chunk prompts, zero/one-token budgets, EOS stopping."""
    _check_invariants([
        (3, 5, False), (MAX_PREFILL, 8, True), (20, 0, False),
        (1, 1, True), (9, 10, False), (17, 2, True),
        (MAX_PREFILL, 1, False), (5, 7, True),
    ])


def test_scheduler_fault_invariants_deterministic():
    """Fault-plan drain invariants on a fixed workload across fixed
    seeds (runs even when hypothesis is absent)."""
    spec = [(3, 5, False), (20, 8, True), (9, 12, False),
            (5, 2, True), (14, 6, False)]
    for seed in range(4):
        _check_fault_invariants(spec, seed=seed, preempt_after=2)
