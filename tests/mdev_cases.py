"""Multi-device test-case BODIES (no pytest here).

Each ``case_*`` function assumes the process already exposes enough
devices (>= 4 unless noted) and raises AssertionError on failure.
They are invoked either in-process (multi-device CI leg) or in a
forced-host-device subprocess — see tests/mdev_harness.py.  Run one
directly with::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src:.:tests python tests/mdev_cases.py case_engine_parity
"""
from __future__ import annotations

import copy
import sys

import numpy as np


def _tiny_cfg():
    from repro.config import ModelConfig
    return ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=128, head_dim=16)


def _workload(rng, n=10, vocab=128):
    """Mixed prompt/output lengths: sub-chunk and multi-chunk prompts
    (prefill_chunk=16 below), immediate-finish budgets, EOS stopping on
    half the requests — with more requests than lanes, so admission
    overlaps in-flight decode."""
    from repro.serving.engine import Request
    plens = [3, 20, 40, 8, 33, 16, 5, 48, 11, 26]
    mnews = [5, 12, 3, 9, 7, 1, 14, 6, 10, 4]
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, size=plens[i % 10])
                    .astype(np.int32),
                    max_new_tokens=mnews[i % 10],
                    eos_id=(7 if i % 2 else None))
            for i in range(n)]


def _serve_pair(mesh):
    """(single-device done, sharded done, single engine, sharded engine)
    over identical workloads."""
    import jax
    from repro.config import RaasConfig
    from repro.models import model as M
    from repro.serving.engine import Engine
    from repro.serving.scheduler import serve

    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    kw = dict(batch_slots=4, max_seq=96, max_prefill=48,
              prefill_chunk=16, chunk_steps=4)
    rng = np.random.default_rng(0)
    reqs = _workload(rng)

    eng1 = Engine(params, cfg, raas, **kw)
    done1 = serve(eng1, copy.deepcopy(reqs))
    eng2 = Engine(params, cfg, raas, mesh=mesh, **kw)
    done2 = serve(eng2, copy.deepcopy(reqs))
    return done1, done2, eng1, eng2


def case_engine_parity():
    """Sharded decode/prefill is byte-identical to the single-device
    engine on a mixed workload with admission overlapping decode, and
    per-device paged-cache bytes shrink by the data-axis size."""
    import jax
    from repro.launch import mesh as mesh_lib

    assert jax.device_count() >= 4, "needs 4 devices (forced host devs)"
    mesh = mesh_lib.make_serving_mesh("data=4")
    done1, done2, eng1, eng2 = _serve_pair(mesh)

    out1 = {r.uid: list(r.output) for r in done1}
    out2 = {r.uid: list(r.output) for r in done2}
    assert out1 == out2, f"sharded outputs diverged: {out1} vs {out2}"
    # honest accounting must match dispatch-for-dispatch
    for field in ("tokens_emitted", "prefill_tokens", "steps_executed",
                  "dispatches", "prefill_dispatches"):
        assert getattr(eng1, field) == getattr(eng2, field), field

    # the paged cache is genuinely lane-sharded: every leaf's
    # addressable shard covers B/4 lanes (NamedSharding shard shapes,
    # no transfer), so per-device bytes are exactly global/4
    B = eng2.B
    for pos_cache in eng2.cache.per_pos:
        for leaf in jax.tree.leaves(pos_cache.attn):
            shard = leaf.sharding.shard_shape(leaf.shape)
            assert shard[1] == B // 4, (leaf.shape, shard)
    g, d = eng2.kv_cache_bytes(), eng2.kv_cache_bytes_per_device()
    assert g == 4 * d, (g, d)
    assert eng1.kv_cache_bytes() == g
    assert eng1.kv_cache_bytes_per_device() == g  # single device: no shrink
    print(f"parity ok: {sum(len(v) for v in out1.values())} tokens, "
          f"kv {g} -> {d} bytes/device")


def case_no_cache_gather():
    """The compiled sharded decode chunk moves strictly less collective
    traffic than one lane's KV pages — no dispatch gathers the cache.
    Lowering depends only on shapes and shardings, so this builds just
    the sharded engine and never serves (cheap in both CI legs)."""
    import jax
    from repro.config import RaasConfig
    from repro.analysis import hlo as H
    from repro.launch import mesh as mesh_lib
    from repro.models import model as M
    from repro.serving.engine import Engine

    assert jax.device_count() >= 4
    mesh = mesh_lib.make_serving_mesh("data=4")
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    eng = Engine(params, cfg, raas, mesh=mesh, batch_slots=4, max_seq=96,
                 max_prefill=48, prefill_chunk=16, chunk_steps=4)
    lowered = eng._chunk_fn.lower(
        eng.params, eng.cache, eng._dev(eng.last_token), eng._dev(eng.pos),
        eng._dev(eng.active), eng._dev(eng.n_emitted), eng._dev(eng.eos_id),
        eng._dev(eng.max_new), steps=eng.chunk_steps)
    txt = lowered.compile().as_text()
    coll = H.collective_bytes(txt)
    per_lane_kv = eng.kv_cache_bytes() // eng.B
    assert coll["total"] < per_lane_kv, (
        f"decode chunk moves {coll} collective bytes — more than one "
        f"lane's KV ({per_lane_kv}); the dispatch is gathering cache")
    print(f"collective bytes {coll['total']:.0f} < per-lane KV {per_lane_kv}")


def case_mesh_model_axis():
    """data=2,model=2: lanes shard over data AND the KV head_dim shards
    over model (the decode rule table), still serving to completion."""
    import jax
    from repro.launch import mesh as mesh_lib

    assert jax.device_count() >= 4
    mesh = mesh_lib.make_serving_mesh("data=2,model=2")
    done1, done2, _eng1, eng2 = _serve_pair(mesh)
    out1 = {r.uid: list(r.output) for r in done1}
    out2 = {r.uid: list(r.output) for r in done2}
    assert out1 == out2, "2D mesh outputs diverged"
    g, d = eng2.kv_cache_bytes(), eng2.kv_cache_bytes_per_device()
    # lanes halve everything; head_dim sharding halves the KV arrays
    # again, so per-device bytes land strictly below global/2
    assert d < g // 2, (g, d)
    print(f"2D mesh ok: kv {g} -> {d} bytes/device")


def case_hlo_collectives_roundtrip():
    """Parse collectives out of an actually-compiled sharded program
    (the unit tests only ever parse a hand-written HLO sample)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis import hlo as H
    from repro.launch import mesh as mesh_lib

    assert jax.device_count() >= 2, "needs >1 device (forced host devs)"
    mesh = mesh_lib.make_serving_mesh(data=2, model=1)
    x = jnp.arange(4096, dtype=jnp.float32).reshape(8, 512)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    fn = jax.jit(lambda a: a.sum(axis=0),
                 out_shardings=NamedSharding(mesh, P()))
    np.testing.assert_allclose(np.asarray(fn(xs)), np.asarray(x.sum(axis=0)))
    txt = fn.lower(xs).compile().as_text()
    counts = H.count_collectives(txt)
    assert sum(counts.values()) >= 1, \
        f"no collectives in sharded-reduction HLO:\n{txt[:2000]}"
    coll = H.collective_bytes(txt)
    assert coll["total"] > 0, (counts, coll)
    print(f"hlo roundtrip ok: {counts} -> {coll['total']:.0f} B/device")


def case_paged_prefill_sharded():
    """Zero-copy paged prefill under the lane-sharded mesh: a
    prefill-heavy workload (long prompts, 1-2 token outputs, so almost
    every dispatch is a bucketed paged-prefill chunk) serves
    byte-identically to the single-device engine, with identical
    analytic prefill traffic and the same O(log S) compile count —
    the bucketed ``ctx_pages`` static arg and the paged kernel's page
    reads trace cleanly under the engine mesh's lane sharding."""
    import copy as _copy

    import jax
    from repro.config import RaasConfig
    from repro.launch import mesh as mesh_lib
    from repro.models import model as M
    from repro.serving.engine import Engine, Request
    from repro.serving.scheduler import serve

    assert jax.device_count() >= 4, "needs 4 devices (forced host devs)"
    mesh = mesh_lib.make_serving_mesh("data=4")
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    kw = dict(batch_slots=4, max_seq=96, max_prefill=64,
              prefill_chunk=8, chunk_steps=4)
    rng = np.random.default_rng(0)
    plens = [60, 33, 48, 12, 57, 40]
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, 128, size=plens[i])
                    .astype(np.int32),
                    max_new_tokens=1 + i % 2)
            for i in range(len(plens))]

    eng1 = Engine(params, cfg, raas, **kw)
    done1 = serve(eng1, _copy.deepcopy(reqs))
    eng2 = Engine(params, cfg, raas, mesh=mesh, **kw)
    done2 = serve(eng2, _copy.deepcopy(reqs))
    out1 = {r.uid: list(r.output) for r in done1}
    out2 = {r.uid: list(r.output) for r in done2}
    assert out1 == out2, f"sharded paged prefill diverged: {out1} vs {out2}"
    for field in ("prefill_tokens", "prefill_dispatches", "prefill_traces",
                  "prefill_kv_bytes", "prefill_kv_bytes_gather"):
        assert getattr(eng1, field) == getattr(eng2, field), field
    # prefill genuinely dominated, went zero-copy, and stayed bucketed
    assert eng2.prefill_tokens > eng2.tokens_emitted
    assert 0 < eng2.prefill_kv_bytes < eng2.prefill_kv_bytes_gather
    bound = (64 // raas.page_size).bit_length() + 1
    assert eng2.prefill_traces <= bound, (eng2.prefill_traces, bound)
    print(f"sharded paged prefill ok: {eng2.prefill_tokens} prompt "
          f"tokens, {eng2.prefill_traces} prefill traces, "
          f"{eng2.prefill_kv_bytes}/{eng2.prefill_kv_bytes_gather} "
          "paged/gather bytes")


def case_preempt_restore_sharded():
    """Lane checkpoint/restore under the lane-sharded mesh: a decode
    preempted mid-chunk from one device's lane and restored onto a
    DIFFERENT device's lane finishes byte-identical to the
    single-device uninterrupted run (rows round-trip through host, so
    the restore crosses shard boundaries), with no leaked pool
    claims."""
    import jax
    from repro.config import RaasConfig
    from repro.launch import mesh as mesh_lib
    from repro.models import model as M
    from repro.serving.engine import PREFILL, Engine, Request
    from repro.serving.scheduler import serve

    assert jax.device_count() >= 4, "needs 4 devices (forced host devs)"
    mesh = mesh_lib.make_serving_mesh("data=4")
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
    kw = dict(batch_slots=4, max_seq=96, max_prefill=48,
              prefill_chunk=16, chunk_steps=4)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 128, size=12).astype(np.int32)

    eng1 = Engine(params, cfg, raas, **kw)
    (base,) = serve(eng1, [Request(uid=0, prompt=prompt.copy(),
                                   max_new_tokens=16)])
    assert base.status == "OK" and len(base.output) > 4

    eng2 = Engine(params, cfg, raas, mesh=mesh, **kw)
    req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=16)
    eng2.admit(req)
    slot = eng2.slot_req.index(req)
    while eng2.phase[slot] == PREFILL:
        eng2.prefill_step()
    eng2.step_chunk()                    # partial progress, then preempt
    ckpt = eng2.checkpoint_lane(slot)
    # B=4 over data=4: every lane lives on its own device, so any
    # other lane is a genuinely different shard
    other = (slot + 2) % eng2.B
    assert eng2.restore_lane(ckpt, other) == other
    done = []
    while eng2.has_active():
        done.extend(eng2.prefill_step())
        done.extend(eng2.step_chunk())
    assert done == [req] and req.done
    assert req.status == "PREEMPTED_RESUMED", req.status
    assert req.output == base.output, \
        f"sharded preempt/restore diverged: {req.output} vs {base.output}"
    assert (eng2.checkpoints, eng2.restores) == (1, 1)
    eng2.audit_refcounts()
    print(f"sharded preempt/restore ok: lane {slot} -> {other}, "
          f"{len(req.output)} tokens byte-identical")


def case_bench_sharded_row():
    """serving_throughput's sharded sweep row: byte-identical outputs
    and the per-device-bytes assertion run inside the benchmark."""
    import jax
    assert jax.device_count() >= 4
    from benchmarks import serving_throughput
    result = serving_throughput.run(n_requests=5, write_json=False,
                                    mesh_spec="data=4")
    shard = result["sharded"]
    assert shard["n_data"] == 4
    assert shard["kv_bytes_per_device"] * 4 == shard["kv_bytes_global"]
    assert shard["tokens_emitted"] == result["continuous"]["tokens_emitted"]
    print("bench sharded row ok")


if __name__ == "__main__":
    case = sys.argv[1]
    getattr(sys.modules["__main__"], case)()
    print(f"{case}: OK")
