"""Preemption, fault injection and graceful degradation.

The resilience contract (repro.serving.resilience):

  * ``checkpoint_lane`` / ``restore_lane`` round-trip a mid-decode
    lane through host memory and resume **byte-identically**, even
    onto a different lane — including a lane whose prompt was mounted
    from the shared prefix index;
  * every seeded :class:`FaultPlan` serve run terminates with every
    request carrying exactly one terminal status, all lanes FREE,
    exact token accounting (emitted == surviving outputs + discarded)
    and zero leaked pool claims (``audit_refcounts``);
  * injected dispatch errors raise *before* the jitted call, so the
    bounded retry path replays to byte parity; exhausting the retry
    budget drains cleanly through ``abort_in_flight`` and leaves the
    engine reusable;
  * the scheduler's degradation policy really checkpoints a long
    decode under admission starvation and restores it unchanged;
  * attaching a plan never touches the compiled dispatches (no host
    transfers appear — the harness is zero-overhead when off).

One engine is shared across tests (same pattern as
tests/test_scheduler_property.py) so the chunk functions compile once.
Every test leaves the engine drained and audited.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H
from repro.config import ModelConfig, RaasConfig
from repro.models import model as M
from repro.serving import resilience as R
from repro.serving.engine import DECODE, FREE, PREFILL, Engine, Request
from repro.serving.scheduler import serve

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   head_dim=16)
MAX_PREFILL = 32

_ENGINE = None


def _engine() -> Engine:
    global _ENGINE
    if _ENGINE is None:
        params = M.init_params(jax.random.PRNGKey(0), TINY)
        raas = RaasConfig(policy="raas", budget_tokens=64, page_size=4)
        _ENGINE = Engine(params, TINY, raas, batch_slots=3, max_seq=64,
                         max_prefill=MAX_PREFILL, prefill_chunk=8,
                         chunk_steps=4)
    return _ENGINE


def _reqs(specs, seed=0):
    """Fresh Request objects from (plen, max_new[, eos]) specs; the
    seeded rng makes prompts identical across parity runs."""
    rng = np.random.default_rng(seed)
    out = []
    for i, spec in enumerate(specs):
        plen, max_new, eos = (spec + (None,))[:3]
        out.append(Request(
            uid=i, prompt=rng.integers(0, TINY.vocab_size,
                                       size=plen).astype(np.int32),
            max_new_tokens=max_new, eos_id=eos))
    return out


def _to_decode(eng, req):
    """Admit ``req`` and pump prefill until its lane decodes; returns
    the lane."""
    eng.admit(req)
    slot = eng.slot_req.index(req)
    while eng.phase[slot] == PREFILL:
        assert not eng.prefill_step(), "request finished during prefill"
    assert eng.phase[slot] == DECODE
    return slot


def _drain(eng):
    done = []
    while eng.has_active():
        done.extend(eng.prefill_step())
        done.extend(eng.step_chunk())
    return done


def _assert_drained(eng):
    assert all(p == FREE for p in eng.phase)
    assert all(r is None for r in eng.slot_req)
    eng.audit_refcounts()


# ---------------------------------------------------------------------------
# checkpoint / restore parity
# ---------------------------------------------------------------------------
def test_checkpoint_restore_different_lane_byte_parity():
    eng = _engine()
    (base,) = serve(eng, _reqs([(6, 12)], seed=11))
    assert base.status == R.OK and len(base.output) > 1

    ck0, rs0 = eng.checkpoints, eng.restores
    (req,) = _reqs([(6, 12)], seed=11)
    slot = _to_decode(eng, req)
    eng.step_chunk()                      # some decode progress first
    assert not req.done
    ckpt = eng.checkpoint_lane(slot)
    assert eng.phase[slot] == FREE and eng.slot_req[slot] is None
    assert not eng.has_active()           # fully off-device
    assert isinstance(np.asarray(jax.tree.leaves(ckpt.rows)[0]),
                      np.ndarray)

    other = (slot + 1) % eng.B
    assert eng.restore_lane(ckpt, other) == other
    done = _drain(eng)
    assert done == [req] and req.done
    assert req.status == R.PREEMPTED_RESUMED
    assert req.output == base.output, "restore broke byte parity"
    assert (eng.checkpoints, eng.restores) == (ck0 + 1, rs0 + 1)
    _assert_drained(eng)


def test_checkpoint_restore_with_mounted_prefix_parity():
    """The preempted lane's prompt was zero-copy mounted from the
    prefix index; its release must keep the donor pages parked, and
    the restored run must still match the uninterrupted one."""
    eng = _engine()
    # park a prompt, then serve the same prompt once uninterrupted
    (a,) = serve(eng, _reqs([(8, 3)], seed=21))
    assert a.status == R.OK
    m0 = eng.prefix_mounts
    (base,) = serve(eng, _reqs([(8, 10)], seed=21))
    assert eng.prefix_mounts > m0, "prompt did not mount from the pool"

    (req,) = _reqs([(8, 10)], seed=21)
    slot = _to_decode(eng, req)
    eng.step_chunk()
    ckpt = eng.checkpoint_lane(slot)
    # the shared prefix survives the preemption: still parked + indexed
    assert eng.pool.covered_pages(slot) > 0
    other = (slot + 1) % eng.B
    eng.restore_lane(ckpt, other)
    _drain(eng)
    assert req.status == R.PREEMPTED_RESUMED
    assert req.output == base.output
    _assert_drained(eng)


def test_checkpoint_api_validation():
    eng = _engine()
    with pytest.raises(ValueError, match="not in decode"):
        eng.checkpoint_lane(0)            # free lane
    (req,) = _reqs([(20, 4)], seed=31)
    eng.admit(req)
    slot = eng.slot_req.index(req)
    eng.prefill_step()                    # 8 of 20 tokens: mid-prefill
    assert eng.phase[slot] == PREFILL
    with pytest.raises(ValueError, match="not in decode"):
        eng.checkpoint_lane(slot)
    while eng.phase[slot] == PREFILL:
        eng.prefill_step()
    ckpt = eng.checkpoint_lane(slot)
    eng.restore_lane(ckpt)
    _drain(eng)
    assert req.done and req.status == R.PREEMPTED_RESUMED
    with pytest.raises(ValueError, match="stale checkpoint"):
        eng.restore_lane(ckpt)            # request already finished
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
def test_fault_plan_seeds_terminate_clean():
    """Every seeded plan terminates with terminal statuses everywhere,
    a drained engine, exact token accounting and zero leaked claims."""
    eng = _engine()
    specs = [(3, 5), (20, 8, 7), (9, 12), (5, 2, 7), (14, 6), (7, 9)]
    total_injected = 0
    for seed in range(8):
        plan = R.FaultPlan(seed=seed, p_dispatch_error=0.25, p_nan=0.15,
                           p_lane_loss=0.1, p_admission_race=0.25,
                           max_faults=10)
        reqs = _reqs(specs, seed=100 + seed)
        e0 = eng.tokens_emitted
        d0 = eng.tokens_discarded
        eng.set_faults(plan)
        try:
            done = serve(eng, reqs, preempt_after=2)
        finally:
            eng.set_faults(None)
        total_injected += sum(plan.injected.values())
        assert sorted(r.uid for r in done) == list(range(len(specs)))
        for r in done:
            assert r.done and r.status in R.TERMINAL_STATUSES, \
                (seed, r.uid, r.status)
        assert eng.tokens_emitted - e0 \
            == sum(len(r.output) for r in done) \
            + (eng.tokens_discarded - d0), f"seed {seed} lost tokens"
        _assert_drained(eng)
    assert total_injected > 0, "no fault ever fired across 8 seeds"


def test_device_nan_quarantines_one_lane():
    """Real non-finite bytes in one lane's pages trip the on-device
    finite mask: that lane is quarantined (FAILED_NAN, poisoned tokens
    discarded) while its batch neighbor decodes on to byte parity."""
    eng = _engine()
    (base,) = serve(eng, _reqs([(5, 8)], seed=41))

    bad, good = _reqs([(6, 8), (5, 8)], seed=41)
    bad.uid, good.uid = 100, 0            # keep prompts: good == base
    good.prompt = base.prompt
    nq0, e0, d0 = eng.nan_quarantines, eng.tokens_emitted, \
        eng.tokens_discarded
    slot_b = _to_decode(eng, bad)
    slot_g = _to_decode(eng, good)

    def poison(cache, lane):
        per = []
        for bc in cache.per_pos:
            attn = bc.attn
            if attn is not None:
                attn = attn._replace(
                    k_pages=attn.k_pages.at[:, lane].set(jnp.nan),
                    v_pages=attn.v_pages.at[:, lane].set(jnp.nan))
            per.append(bc._replace(attn=attn))
        return cache._replace(per_pos=tuple(per))

    eng.cache = poison(eng.cache, slot_b)
    done = _drain(eng)
    assert {r.uid for r in done} == {100, 0}
    assert bad.status == R.FAILED_NAN
    assert eng.nan_quarantines == nq0 + 1
    assert eng.tokens_discarded > d0, "poisoned tokens were kept"
    assert good.status == R.OK
    assert good.output == base.output, "quarantine leaked into the batch"
    assert eng.tokens_emitted - e0 == len(bad.output) + len(good.output) \
        + (eng.tokens_discarded - d0)
    _assert_drained(eng)
    # quarantine scrubbed the payload: fresh requests filling EVERY
    # lane (including the poisoned one) decode clean — the
    # metadata-only reset alone would let them inherit the NaN bytes
    again = serve(eng, _reqs([(4, 6), (6, 6), (8, 6)], seed=43))
    assert all(r.status == R.OK and len(r.output) > 0 for r in again)
    _assert_drained(eng)


def test_injected_dispatch_errors_retry_to_parity():
    """p=1.0 transient errors with max_consecutive_errors below the
    retry limit: every dispatch eventually lands and the run is
    byte-identical to the fault-free one."""
    eng = _engine()
    specs = [(3, 6), (12, 4, 7), (9, 8)]
    base = {r.uid: list(r.output) for r in serve(eng, _reqs(specs, seed=51))}
    plan = R.FaultPlan(seed=3, p_dispatch_error=1.0,
                       max_consecutive_errors=2, max_faults=10_000)
    r0 = eng.retries
    eng.set_faults(plan)
    try:
        done = serve(eng, _reqs(specs, seed=51))
    finally:
        eng.set_faults(None)
    assert plan.injected["dispatch_error"] > 0 and eng.retries > r0
    assert all(r.status == R.OK for r in done)
    assert {r.uid: list(r.output) for r in done} == base, \
        "retry replay broke byte parity"
    _assert_drained(eng)


def test_retry_exhaustion_drains_clean_and_engine_survives():
    """Errors outlasting the retry budget surface as
    DispatchFailedError; the scheduler's drain path terminal-fails the
    in-flight requests, leaks nothing, and the engine serves again."""
    eng = _engine()
    plan = R.FaultPlan(seed=7, p_dispatch_error=1.0,
                       max_consecutive_errors=10, max_faults=10_000)
    reqs = _reqs([(4, 5), (6, 3)], seed=61)
    eng.set_faults(plan)
    try:
        with pytest.raises(R.DispatchFailedError):
            serve(eng, reqs)
    finally:
        eng.set_faults(None)
    for r in reqs:
        assert r.done and r.status == R.FAILED_DISPATCH
    _assert_drained(eng)
    # the engine is still serviceable after the failure drain
    (again,) = serve(eng, _reqs([(4, 5)], seed=61))
    assert again.status == R.OK and len(again.output) > 0
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# scheduler: rejection + graceful degradation
# ---------------------------------------------------------------------------
def test_rejected_request_gets_terminal_status():
    eng = _engine()
    good, too_long = _reqs([(5, 3), (MAX_PREFILL + 8, 3)], seed=71)
    done = serve(eng, [too_long, good])
    assert too_long.done and too_long.status == R.REJECTED
    assert too_long.output == []
    assert good.status == R.OK and len(done) == 2
    _assert_drained(eng)


def test_degradation_preempts_long_decode_under_pressure():
    """More requests than lanes, every lane stuck in a long decode:
    after ``preempt_after`` starved boundaries the scheduler must
    checkpoint the youngest long decode, admit the queue, restore when
    pressure clears — and change no output bytes."""
    eng = _engine()
    specs = [(4, 20), (5, 20), (6, 20), (3, 2), (4, 2)]
    base = {r.uid: list(r.output)
            for r in serve(eng, _reqs(specs, seed=81), preempt_after=0)}
    ck0, rs0 = eng.checkpoints, eng.restores
    done = serve(eng, _reqs(specs, seed=81), preempt_after=2)
    assert eng.checkpoints > ck0, "pressure never triggered a preemption"
    assert eng.restores > rs0, "checkpoint was never restored"
    assert {r.uid: list(r.output) for r in done} == base, \
        "preemption changed output bytes"
    statuses = {r.uid: r.status for r in done}
    assert set(statuses.values()) <= {R.OK, R.PREEMPTED_RESUMED}
    assert R.PREEMPTED_RESUMED in statuses.values()
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# zero overhead when off
# ---------------------------------------------------------------------------
def test_fault_hooks_leave_dispatch_hlo_clean():
    """A FaultPlan is consulted strictly host-side: with a plan
    attached, the compiled decode dispatch still contains no host
    transfers and still donates the cache."""
    eng = _engine()
    eng.set_faults(R.FaultPlan(seed=0, p_dispatch_error=0.5, p_nan=0.5))
    try:
        lowered = eng._chunk_fn.lower(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                                        x.dtype),
                         eng.params),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                                        x.dtype),
                         eng.cache),
            *([jax.ShapeDtypeStruct((eng.B,), jnp.int32)] * 2),
            jax.ShapeDtypeStruct((eng.B,), jnp.bool_),
            *([jax.ShapeDtypeStruct((eng.B,), jnp.int32)] * 3),
            steps=eng.chunk_steps)
        txt = lowered.compile().as_text()
    finally:
        eng.set_faults(None)
    assert H.host_transfer_findings(txt, label="decode_chunk") == []
    assert "input_output_alias" in txt, "cache donation disappeared"
