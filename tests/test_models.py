"""Model-level correctness: train-forward vs prefill+decode equivalence,
MoE dispatch vs dense reference, mamba2 parallel/sequential duality.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ATTN, FFN_DENSE, FFN_MOE, MAMBA, MambaConfig,
                          ModelConfig, MoEConfig, RaasConfig)
from repro.models import mamba2, model as M, moe

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=4, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                   head_dim=16, qk_norm=True)

HYBRID = ModelConfig(
    name="tiny-hybrid", arch_type="hybrid", n_layers=6, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16,
    period=((MAMBA, FFN_DENSE), (ATTN, FFN_MOE), (MAMBA, FFN_DENSE)),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=64),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk_size=8))


def _teacher_force(cfg, params, tokens, raas, pre_len):
    B, T = tokens.shape[:2]
    cache = M.init_model_cache(cfg, raas, B, max_seq_len=T,
                               prefill_len=pre_len)
    lengths = jnp.full((B,), pre_len)
    cache, lg0 = M.prefill(params, cfg, tokens[:, :pre_len], lengths,
                           cache)
    logits = [lg0]
    for t in range(pre_len, T):
        cache, lg = M.decode_step(params, cfg, tokens[:, t],
                                  jnp.full((B,), t), cache, raas)
        logits.append(lg)
    return jnp.stack(logits, axis=1), cache


@pytest.mark.parametrize("policy", ["dense", "quest", "raas"])
def test_decode_matches_train_forward(policy):
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    B, T, pre = 2, 24, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 97)
    ref, _ = M.forward_train(params, TINY, tokens, remat=False)
    raas = RaasConfig(policy=policy, budget_tokens=256, page_size=4,
                      quest_topk_pages=64)
    got, _ = _teacher_force(TINY, params, tokens, raas, pre)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref[:, pre - 1:T]),
                               atol=1e-4, rtol=1e-4)


def test_hybrid_decode_matches_train_forward():
    params = M.init_params(jax.random.PRNGKey(0), HYBRID)
    B, T, pre = 2, 16, 6
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 97)
    ref, _ = M.forward_train(params, HYBRID, tokens, remat=False,
                             capacity_factor=8.0)
    raas = RaasConfig(policy="dense", budget_tokens=64, page_size=4)
    got, _ = _teacher_force(HYBRID, params, tokens, raas, pre)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref[:, pre - 1:T]),
                               atol=1e-3, rtol=1e-3)


def test_raas_tight_budget_bounds_memory():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    B, T, pre = 1, 40, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 97)
    raas = RaasConfig(policy="raas", budget_tokens=16, page_size=4)
    _, cache = _teacher_force(TINY, params, tokens, raas, pre)
    attn = cache.per_pos[0].attn
    # stacked [n_periods, B, KV, S, P, hd]: slot axis is dim 3
    assert attn.k_pages.shape[3] == 4          # O(L) slots, static
    assert int(attn.page_len.sum()) <= 4 * 4 * TINY.n_layers


def test_remat_forward_matches():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    a, _ = M.forward_train(params, TINY, tokens, remat=False)
    b, _ = M.forward_train(params, TINY, tokens, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_capacity_dispatch_matches_dense_reference():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32)
    params = moe.init_moe(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    # capacity C >= N guarantees no drops -> exact match
    y1, aux = moe.moe_ffn(params, x, cfg, capacity_factor=100.0)
    y2 = moe.moe_ffn_dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) > 0


def test_moe_dropping_under_tight_capacity():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=8)
    params = moe.init_moe(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    y_tight, _ = moe.moe_ffn(params, x, cfg, capacity_factor=0.25)
    y_ample, _ = moe.moe_ffn(params, x, cfg, capacity_factor=100.0)
    # tight capacity drops tokens (outputs differ), but stays finite
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.abs(y_tight - y_ample).max()) > 0


def test_moe_grad_flows():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16)
    params = moe.init_moe(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))

    def loss(p):
        y, aux = moe.moe_ffn(p, x, cfg, capacity_factor=4.0)
        return (y ** 2).sum() + aux

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert bool(jnp.isfinite(v).all()), k
    assert float(jnp.abs(g["router"]).max()) > 0  # aux reaches router


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------
def test_mamba_parallel_sequential_duality():
    cfg = MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=8,
                      chunk_size=8)
    D, B, T = 32, 2, 20
    params = mamba2.init_mamba(jax.random.PRNGKey(0), D, cfg,
                               jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, D)) * 0.5
    y_par, st = mamba2.mamba_forward(params, u, cfg, D,
                                     return_state=True)
    state = mamba2._init_state(B, D, cfg, jnp.float32)
    ys = []
    for t in range(T):
        y, state = mamba2.mamba_step(params, u[:, t], state, cfg, D)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_par), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.ssm), np.asarray(st.ssm),
                               atol=1e-5)


def test_mamba_chunk_size_invariance():
    D, B, T = 32, 1, 24
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, D)) * 0.5
    outs = []
    for cs in (4, 8, 24):
        cfg = MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                          chunk_size=cs)
        params = mamba2.init_mamba(jax.random.PRNGKey(0), D, cfg,
                                   jnp.float32)
        outs.append(mamba2.mamba_forward(params, u, cfg, D))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               atol=1e-5)


def test_mamba_grad_finite():
    cfg = MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                      chunk_size=8)
    D = 16
    params = mamba2.init_mamba(jax.random.PRNGKey(0), D, cfg,
                               jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (1, 16, D))

    def loss(p):
        return (mamba2.mamba_forward(p, u, cfg, D) ** 2).sum()

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())
