"""Shared pytest configuration.

When ``REPRO_CI=1`` (set by the GitHub Actions workflow), the seed's
known kernel failures listed in ``tests/known_failures.txt`` are
marked ``xfail`` — the CPU-only runner cannot exercise the Pallas TPU
kernels — so a regression in any currently-passing test fails the
build while the known list stays explicit and auditable.  Local runs
are unaffected.
"""
import os
from pathlib import Path

import pytest


def _known_failures():
    path = Path(__file__).with_name("known_failures.txt")
    return {line.strip() for line in path.read_text().splitlines()
            if line.strip() and not line.startswith("#")}


def pytest_collection_modifyitems(config, items):
    if not os.environ.get("REPRO_CI"):
        return
    known = _known_failures()
    for item in items:
        if item.nodeid in known:
            item.add_marker(pytest.mark.xfail(
                reason="known seed kernel failure "
                       "(see tests/known_failures.txt)",
                strict=False))
