"""Shared pytest configuration.

When ``REPRO_CI=1`` (set by the GitHub Actions workflow), tests listed
in ``tests/known_failures.txt`` are marked **strict** ``xfail``: a
listed test that fails is reported as expected, but a listed test that
*passes* (XPASS) fails the build — a stale entry can never keep
masking a test that has started working.  Remove the line the moment a
kernel is fixed.  Local runs are unaffected.

Node ids in the list that point at deleted tests/parametrizations fail
collection loudly instead of silently shrinking the guarded set; the
staleness check only considers test files that were actually collected,
so partial runs (``pytest tests/test_models.py``, ``-k`` selections)
are unaffected.
"""
import os
from pathlib import Path

import pytest


def _known_failures():
    path = Path(__file__).with_name("known_failures.txt")
    return {line.strip() for line in path.read_text().splitlines()
            if line.strip() and not line.startswith("#")}


def pytest_collection_modifyitems(config, items):
    if not os.environ.get("REPRO_CI"):
        return
    known = _known_failures()
    seen = set()
    for item in items:
        if item.nodeid in known:
            seen.add(item.nodeid)
            item.add_marker(pytest.mark.xfail(
                reason="known seed kernel failure "
                       "(see tests/known_failures.txt)",
                strict=True))
    collected_files = {item.nodeid.split("::", 1)[0] for item in items}
    stale = {k for k in known - seen
             if k.split("::", 1)[0] in collected_files}
    if stale:
        raise pytest.UsageError(
            "tests/known_failures.txt lists node ids that no longer "
            f"exist (delete the stale lines): {sorted(stale)}")
