"""Sharded decode serving under a mesh: multi-device parity tests.

The case bodies live in tests/mdev_cases.py and execute on EVERY
machine: in-process when pytest already runs with >= 4 devices (the
multi-device CI leg forces host devices via XLA_FLAGS), otherwise in a
forced-host-device subprocess (tests/mdev_harness.py) — never a silent
skip.

What is pinned down:
  * sharded decode/prefill outputs are **byte-identical** to the
    single-device engine on a mixed prompt/output workload with more
    requests than lanes (admission genuinely overlaps in-flight
    decode), EOS stopping and immediate-finish budgets included;
  * per-device paged-cache bytes shrink by exactly the data-axis size,
    asserted from ``NamedSharding`` addressable-shard shapes;
  * the compiled decode chunk's collective traffic stays below one
    lane's KV bytes — no dispatch gathers the cache;
  * a 2D ``data=2,model=2`` mesh serves identically with the KV
    head_dim sharded over "model" on top of the lane sharding;
  * the serving-throughput benchmark's sharded row runs its own
    byte-parity and per-device-bytes assertions;
  * a decode checkpointed from one device's lane and restored onto a
    different device's lane resumes byte-identically (preemption under
    the mesh crosses shard boundaries through host rows).
"""
import pytest

from mdev_harness import run_case


def test_mesh_spec_parsing():
    """Pure spec-string validation (no devices touched)."""
    from repro.launch.mesh import parse_mesh_spec
    assert parse_mesh_spec("data=4") == (("data", 4), ("model", 1))
    assert dict(parse_mesh_spec("data=2,model=2")) \
        == {"data": 2, "model": 2}
    for bad in ("=4", "data=2,=2", "data=", "data=x", "data=0",
                "data=2,data=2", "model=2"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


def test_serve_config_mesh_validation():
    """ServeConfig validates the spec without initializing devices."""
    from repro.config import ServeConfig
    ServeConfig(batch_slots=4, mesh="data=4")       # whole lanes/device
    with pytest.raises(ValueError, match="divisible"):
        ServeConfig(batch_slots=2, mesh="data=4")   # ragged lane shards
    with pytest.raises(ValueError, match="no 'data' axis"):
        ServeConfig(batch_slots=4, mesh="model=4")


def test_sharded_engine_byte_parity():
    run_case("case_engine_parity")


def test_sharded_decode_no_cache_gather():
    run_case("case_no_cache_gather")


def test_sharded_engine_2d_mesh():
    run_case("case_mesh_model_axis")


def test_bench_sharded_row():
    run_case("case_bench_sharded_row")


def test_sharded_preempt_restore():
    run_case("case_preempt_restore_sharded")
