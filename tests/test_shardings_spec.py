"""Golden-spec tests for the launch/shardings.py rule table.

The rule table decides where every parameter and cache byte of every
arch lives on the mesh — and until now had zero direct coverage (a
path-rendering bug could, and did, silently disable whole rules: the
``GetAttrKey`` regression below).  Three layers of defence:

  * **divisibility sweep** — for every arch config in ``configs/``,
    every leaf, every mode (train / decode / engine): any dim the rule
    table assigns to a mesh axis must actually be divisible by that
    axis size, or the partitioner would pad or gather silently;
  * **golden snapshots** — exact PartitionSpecs for representative
    leaves of a dense-attention arch (qwen3-8b), an MoE arch
    (olmoe-1b-7b) and a hybrid SSM arch (jamba) in each mode, so a
    rule-table edit that re-lays-out a flagship arch fails loudly;
  * **regression** — NamedTuple field names (the paged cache's
    ``k_pages`` etc.) must round-trip through real
    ``cache_shardings`` / ``params_shardings`` calls: jax renders
    those paths as ``GetAttrKey`` whose ``str()`` is ".k_pages", which
    used to defeat every name-match rule silently.
"""
from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ARCH_IDS, RaasConfig, get_config
from repro.launch import shardings as S
from repro.models import model as M

# pspec-level tests need axis SIZES only, so no real devices: the rule
# table reads mesh.shape alone.
FAKE_MESH = SimpleNamespace(shape={"data": 2, "model": 4})
DATA, MODEL = 2, 4
MODES = ("train", "decode", "engine")


def _param_leaves(cfg):
    spec = jax.eval_shape(lambda k: M.init_params(k, cfg),
                          jax.random.PRNGKey(0))
    return [(S._path_str(path), leaf.shape) for path, leaf
            in jax.tree_util.tree_flatten_with_path(spec)[0]]


def _cache_leaves(cfg, batch=8, max_seq=4096, prefill=1024):
    raas = RaasConfig(budget_tokens=1024, page_size=16)
    spec = jax.eval_shape(
        lambda: M.init_model_cache(cfg, raas, batch, max_seq,
                                   prefill_len=prefill))
    return [(S._path_str(path), leaf.shape) for path, leaf
            in jax.tree_util.tree_flatten_with_path(spec)[0]]


def _axis_size(entry) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= {"data": DATA, "model": MODEL}[a]
    return n


def _assert_divisible(path, shape, pspec):
    assert len(pspec) <= len(shape), (path, shape, pspec)
    for i, entry in enumerate(pspec):
        if entry is None:
            continue
        size = _axis_size(entry)
        assert shape[i] % size == 0, (
            f"{path}: dim {i} of {shape} sharded over {entry!r} "
            f"(size {size}) does not divide — the partitioner would "
            "pad or gather")


# ---------------------------------------------------------------------------
# divisibility sweep: every arch, every leaf, every mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_pspec_divisible_all_modes(arch):
    cfg = get_config(arch)
    leaves = _param_leaves(cfg)
    assert leaves, arch
    for mode in MODES:
        for path, shape in leaves:
            ps = S.param_pspec(path, shape, cfg, mode, MODEL, DATA,
                               fsdp=(mode == "train"))
            _assert_divisible(f"{arch}:{mode}:{path}", shape, ps)
            # block leaves carry a leading [n_periods] scan-stack dim
            # that must never be sharded
            if path.startswith("blocks") and len(ps) > 0:
                assert ps[0] is None, (arch, mode, path, ps)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_pspec_divisible_engine_mode(arch):
    cfg = get_config(arch)
    for path, shape in _cache_leaves(cfg):
        ps = S.cache_pspec(path, shape, 8, ("data",), FAKE_MESH, MODEL)
        _assert_divisible(f"{arch}:engine:{path}", shape, ps)
        # period-stack dim (0) is never sharded; the lane dim (1) is
        # sharded over data exactly when divisible (batch=8, data=2)
        assert ps[0] is None, (arch, path, ps)
        if len(shape) >= 2 and shape[1] == 8:
            assert ps[1] == ("data",), (arch, path, ps)


def test_engine_mode_params_follow_decode_rules():
    """Engine mode is decode's param rule table, verbatim."""
    for arch in ("qwen3-8b", "olmoe-1b-7b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        for path, shape in _param_leaves(cfg):
            assert S.param_pspec(path, shape, cfg, "engine", MODEL, DATA) \
                == S.param_pspec(path, shape, cfg, "decode", MODEL, DATA), \
                (arch, path)


def test_unknown_mode_rejected():
    cfg = get_config("qwen3-8b")
    with pytest.raises(ValueError, match="unknown sharding mode"):
        S.param_pspec("embed", (1, 64, 64), cfg, "serve", MODEL, DATA)


# ---------------------------------------------------------------------------
# golden snapshots (axis sizes data=2, model=4)
# ---------------------------------------------------------------------------
GOLDEN_PARAMS = {
    # dense attention (qwen3-8b): head-parallel in train,
    # head_dim-parallel in decode
    ("qwen3-8b", "train", "blocks/0/attn/wq"): P(None, "data", "model", None),
    ("qwen3-8b", "decode", "blocks/0/attn/wq"): P(None, None, None, "model"),
    ("qwen3-8b", "train", "blocks/0/attn/wo"): P(None, "model", None, "data"),
    ("qwen3-8b", "decode", "blocks/0/attn/wo"): P(None, None, "model", None),
    ("qwen3-8b", "train", "blocks/0/ffn/w_gate"): P(None, "data", "model"),
    ("qwen3-8b", "decode", "blocks/0/ffn/w_down"): P(None, "model", None),
    ("qwen3-8b", "train", "embed"): P(None, "model", "data"),
    ("qwen3-8b", "decode", "lm_head"): P(None, None, "model"),
    ("qwen3-8b", "train", "norm_f/scale"): P("data"),
    ("qwen3-8b", "decode", "norm_f/scale"): P(None),
    # MoE (olmoe): expert-parallel both modes; FSDP rides the hidden dim
    ("olmoe-1b-7b", "train", "blocks/0/moe/w_gate"):
        P(None, "model", None, "data"),
    ("olmoe-1b-7b", "decode", "blocks/0/moe/w_gate"):
        P(None, "model", None, None),
    ("olmoe-1b-7b", "train", "blocks/0/moe/w_down"):
        P(None, "model", "data", None),
    ("olmoe-1b-7b", "decode", "blocks/0/moe/router"): P(None, None, "model"),
    # SSM (mamba2): head/hidden-parallel, mode-independent
    ("mamba2-780m", "train", "blocks/0/mamba/A_log"): P(None, "model"),
    ("mamba2-780m", "decode", "blocks/0/mamba/A_log"): P(None, "model"),
    ("mamba2-780m", "decode", "blocks/0/mamba/conv_x_w"):
        P(None, None, "model"),
}


def test_param_pspec_golden():
    leaves = {}
    for arch in {a for a, _m, _p in GOLDEN_PARAMS}:
        leaves[arch] = dict(_param_leaves(get_config(arch)))
    for (arch, mode, path), want in GOLDEN_PARAMS.items():
        shape = leaves[arch][path]
        got = S.param_pspec(path, shape, get_config(arch), mode, MODEL,
                            DATA, fsdp=(mode == "train"))
        assert got == want, f"{arch}:{mode}:{path}: {got} != {want}"


GOLDEN_CACHE = {
    # paged KV (lane-major page-major [.., B, KV, S, P, hd]): lanes over
    # data, head_dim over model; metadata lanes-only
    ("qwen3-8b", "per_pos/0/attn/k_pages"):
        P(None, ("data",), None, None, None, "model"),
    ("qwen3-8b", "per_pos/0/attn/rep_min"):
        P(None, ("data",), None, None, "model"),
    ("qwen3-8b", "per_pos/0/attn/priority"): P(None, ("data",), None),
    ("qwen3-8b", "per_pos/0/attn/active_slot"): P(None, ("data",)),
    # hybrid SSM state: heads over model, lanes over data
    ("jamba-1.5-large-398b", "per_pos/0/mamba/ssm"):
        P(None, ("data",), "model", None, None),
    ("jamba-1.5-large-398b", "per_pos/0/mamba/conv_x"):
        P(None, ("data",), None, "model"),
    ("jamba-1.5-large-398b", "per_pos/4/attn/v_pages"):
        P(None, ("data",), None, None, None, "model"),
}


def test_cache_pspec_golden():
    leaves = {}
    for arch in {a for a, _p in GOLDEN_CACHE}:
        leaves[arch] = dict(_cache_leaves(get_config(arch)))
    for (arch, path), want in GOLDEN_CACHE.items():
        shape = leaves[arch][path]
        got = S.cache_pspec(path, shape, 8, ("data",), FAKE_MESH, MODEL)
        assert got == want, f"{arch}:{path}: {got} != {want}"


# ---------------------------------------------------------------------------
# lane (engine per-lane buffer) rules
# ---------------------------------------------------------------------------
def test_lane_pspec_golden():
    assert S.lane_pspec(4, 4) == P("data")
    assert S.lane_pspec(4, 2, ndim=2) == P("data", None)
    assert S.lane_pspec(8, 4, ndim=2, lane_axis=1) == P(None, "data")
    # non-divisible lane counts fall back to replicated, never ragged
    assert S.lane_pspec(3, 2) == P(None)


# ---------------------------------------------------------------------------
# GetAttrKey path-rendering regression, through the REAL entry points
# ---------------------------------------------------------------------------
def test_namedtuple_paths_reach_rule_table():
    """cache_shardings on the real ModelCache tree must resolve
    NamedTuple field names: with the old ``str(GetAttrKey)`` rendering
    every cache path ended in ".k_pages" and the head_dim/ssm rules
    never fired (caches silently lost their model-axis sharding)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-8b")
    raas = RaasConfig(budget_tokens=1024, page_size=16)
    cache_like = jax.eval_shape(
        lambda: M.init_model_cache(cfg, raas, 2, 256, prefill_len=64))
    shd = S.engine_state_shardings(cache_like, 2, mesh)
    flat = {S._path_str(p): s for p, s
            in jax.tree_util.tree_flatten_with_path(shd)[0]}
    k_pages = next(v for k, v in flat.items() if k.endswith("k_pages"))
    assert k_pages.spec[-1] == "model", k_pages.spec
    assert k_pages.spec[1] == ("data",), k_pages.spec
    cur_len = next(v for k, v in flat.items() if k.endswith("cur_len"))
    assert cur_len.spec == P(None, ("data",)), cur_len.spec
