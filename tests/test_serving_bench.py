"""Serving-throughput benchmark as a test.

The smoke variant runs the full continuous-vs-sequential comparison on
a tight token budget (few requests, short outputs) so tier-1 stays
fast; the benchmark's own assertions are the point — true emitted-token
accounting, byte-identical outputs under batching, and admission
overlapping decode (strictly fewer dispatches than the sequential
baseline).  The ``slow`` variant runs the full sweep that also writes
``BENCH_serving.json`` when invoked through ``benchmarks/run.py``.
"""
import pytest

from benchmarks import serving_throughput


def test_serving_throughput_smoke():
    """Tight budget: 5 requests covering sub-chunk and multi-chunk
    prompts; all the benchmark's honesty assertions run inside."""
    result = serving_throughput.run(n_requests=5, write_json=False)
    cont, seq = result["continuous"], result["sequential"]
    assert cont["dispatches"] < seq["dispatches"]
    assert cont["tokens_emitted"] == seq["tokens_emitted"] > 0
    # multi-chunk ingest really happened (128-token prompt, 32/dispatch)
    assert cont["prefill_dispatches"] > 1


@pytest.mark.slow
def test_serving_throughput_full_sweep():
    result = serving_throughput.run(n_requests=15, write_json=False)
    assert result["continuous"]["dispatches"] \
        < result["sequential"]["dispatches"]
