"""Serving-throughput benchmark as a test.

The smoke variant runs the full continuous-vs-sequential comparison on
a tight token budget (few requests, short outputs) so tier-1 stays
fast; the benchmark's own assertions are the point — true emitted-token
accounting, byte-identical outputs under batching, and admission
overlapping decode (strictly fewer dispatches than the sequential
baseline).  The ``slow`` variant runs the full sweep that also writes
``BENCH_serving.json`` when invoked through ``benchmarks/run.py``.
"""
import pytest

from benchmarks import serving_throughput


def test_serving_throughput_smoke():
    """Tight budget: 5 requests covering sub-chunk and multi-chunk
    prompts; all the benchmark's honesty assertions run inside —
    including the prefill-heavy row's paged-vs-gather analytic-bytes
    comparison and the ctx_pages jit-cache bound."""
    result = serving_throughput.run(n_requests=5, write_json=False)
    cont, seq = result["continuous"], result["sequential"]
    assert cont["dispatches"] < seq["dispatches"]
    assert cont["tokens_emitted"] == seq["tokens_emitted"] > 0
    # multi-chunk ingest really happened (128-token prompt, 32/dispatch)
    assert cont["prefill_dispatches"] > 1
    # zero-copy prefill: the paged kernel's analytic bytes/prompt-token
    # strictly beat what the token-major gather path would have paid
    ph = result["prefill_heavy"]
    assert 0 < ph["prefill_bytes_per_token"] \
        < ph["prefill_bytes_per_token_gather"]
    assert ph["prefill_tokens"] > ph["tokens_emitted"]  # truly prefill-heavy
    # cache donation holds on every jitted dispatch and is no worse
    # than one full KV cache (the second live copy it removes)
    don = result["donation"]
    assert don["donation_saved_bytes"] >= don["kv_cache_bytes"] > 0
    assert don["peak_live_bytes"] + don["kv_cache_bytes"] \
        <= don["peak_live_bytes_undonated"]
    assert set(don["per_dispatch"]) \
        == {"reset", "prefill_chunk", "decode_chunk", "pool_transition",
            "lane_restore"}
    # shared-prefix row: the byte-parity assertion runs inside run();
    # here pin the schema and the collapse accounting it exposes
    assert result["schema"] == "serving/v6-preemption"
    sp = result["prefix_cache"]
    assert sp["prefix_caching"] is True
    assert sp["prefix_mounts"] + sp["prefix_clones"] >= 1
    assert sp["prefix_cached_tokens"] > 0
    assert sp["prefill_tokens"] \
        == sp["prefill_tokens_uncached"] - sp["prefix_cached_tokens"]
    assert 0 < sp["prefill_collapse"] < 1
    # preemption row: byte parity vs the uninterrupted fleet runs
    # inside run(); here pin that degradation really fired and that
    # the warm checkpoint/restore microbench produced real timings
    pre = result["preemption"]
    assert pre["checkpoints"] >= 1 and pre["restores"] >= 1
    assert set(pre["statuses"]) <= {"OK", "PREEMPTED_RESUMED"}
    assert "PREEMPTED_RESUMED" in pre["statuses"]
    assert pre["checkpoint_s"] > 0 and pre["restore_s"] > 0


@pytest.mark.slow
def test_serving_throughput_full_sweep():
    result = serving_throughput.run(n_requests=15, write_json=False)
    assert result["continuous"]["dispatches"] \
        < result["sequential"]["dispatches"]


@pytest.mark.slow
def test_serving_prefill_heavy_full_sweep():
    """Full-budget prefill-heavy sweep (long prompts, 1-3 token
    outputs): the paged path's analytic savings at scale."""
    result = serving_throughput.run(n_requests=20, write_json=False)
    ph = result["prefill_heavy"]
    assert ph["prefill_kv_bytes"] < ph["prefill_kv_bytes_gather"]
