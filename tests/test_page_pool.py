"""Page-pool tests: refcount claims, lane transitions, copy-on-write,
the prefix index, and session ids.

The load-bearing property (hypothesis when available, a deterministic
multi-seed walk otherwise): under protocol-legal sequences of appends
and lane transitions, a slot whose ``refcount`` exceeds one — a parked
session or the prefix index still needs its bytes — is never evicted,
overwritten or reset; its KV bytes and metadata are bit-frozen until
its claims drop.  Copy-on-write is pinned separately: appending into a
shared active page diverts into a private copy whose bytes match an
unshared control lane exactly, while the shared page stays bit-exact.
"""
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        def deco(fn):
            @_SKIP
            @functools.wraps(fn)
            def stub(*args, **kwargs):
                raise AssertionError("unreachable: test is skipped")
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

import jax
import jax.numpy as jnp

from repro.core import page_pool as pool
from repro.core import paged_cache as pc

S, P, KV, HD = 8, 4, 2, 4
SPEC = pc.CacheSpec(n_slots=S, page_size=P, n_kv_heads=KV, head_dim=HD)


def _prefilled(rng, B=2, length=8):
    """Fresh cache with ``length`` prefill tokens per lane."""
    cache = pc.init_cache(SPEC, B)
    k = rng.standard_normal((B, length, KV, HD)).astype(np.float32)
    v = rng.standard_normal((B, length, KV, HD)).astype(np.float32)
    return pc.ingest_prefill(cache, jnp.asarray(k), jnp.asarray(v),
                             jnp.full((B,), length, jnp.int32))


def _lane_op(cache, lane, op, a0=0, a1=0):
    """Apply one transition to one lane (the others NOP)."""
    B = cache.cur_len.shape[-1]
    ops = np.zeros(B, np.int32)
    ops[lane] = op
    av0, av1 = np.zeros(B, np.int32), np.zeros(B, np.int32)
    av0[lane], av1[lane] = a0, a1
    return pool.transition_lanes(cache, jnp.asarray(ops),
                                 jnp.asarray(av0), jnp.asarray(av1))


def _append(cache, rng, lanes=None):
    B = cache.cur_len.shape[-1]
    k = rng.standard_normal((B, KV, HD)).astype(np.float32)
    v = rng.standard_normal((B, KV, HD)).astype(np.float32)
    wm = None
    if lanes is not None:
        wm = np.zeros(B, bool)
        wm[list(lanes)] = True
        wm = jnp.asarray(wm)
    prio = cache.cur_len.astype(jnp.float32)
    return pc.append_token(cache, jnp.asarray(k), jnp.asarray(v), prio,
                           write_mask=wm)


# ---------------------------------------------------------------------------
# transition op semantics
# ---------------------------------------------------------------------------
def test_incref_release_park_cycle():
    rng = np.random.default_rng(0)
    cache = _prefilled(rng, B=2, length=8)          # 2 full pages, rc=1
    cache = _lane_op(cache, 0, pool.OP_INCREF, 0, 2)
    np.testing.assert_array_equal(cache.refcount[0], [2, 2] + [0] * 6)
    k_before = np.asarray(cache.k_pages[0])

    cache = _lane_op(cache, 0, pool.OP_RELEASE)
    # index claim survives: pages parked, bytes + layout intact
    np.testing.assert_array_equal(cache.refcount[0], [1, 1] + [0] * 6)
    np.testing.assert_array_equal(cache.page_len[0, :2], [P, P])
    np.testing.assert_array_equal(np.asarray(cache.k_pages[0]), k_before)
    assert int(cache.cur_len[0]) == 0 and int(cache.active_slot[0]) == -1
    # lane 1 (NOP throughout) is untouched
    np.testing.assert_array_equal(cache.refcount[1], [1, 1] + [0] * 6)
    assert int(cache.cur_len[1]) == 8

    # release without an index claim wipes the lane entirely
    cache = _lane_op(cache, 1, pool.OP_RELEASE)
    np.testing.assert_array_equal(cache.refcount[1], 0)
    np.testing.assert_array_equal(cache.page_len[1], 0)
    np.testing.assert_array_equal(cache.page_pos[1], -1)


def test_mount_is_byte_identical_to_fresh_prefill():
    rng = np.random.default_rng(1)
    cache = _prefilled(rng, B=2, length=8)
    control = cache                                  # lane state pre-park
    cache = _lane_op(cache, 0, pool.OP_INCREF, 0, 2)
    cache = _lane_op(cache, 0, pool.OP_RELEASE)      # park
    cache = _lane_op(cache, 0, pool.OP_MOUNT, 8)     # resume all 8 tokens

    for name in ("k_pages", "v_pages", "rep_min", "rep_max", "priority",
                 "page_pos", "page_len", "pinned", "active_slot",
                 "cur_len"):
        np.testing.assert_array_equal(
            np.asarray(getattr(cache, name)[0]),
            np.asarray(getattr(control, name)[0]), err_msg=name)
    # mounted pages carry request + index claims
    np.testing.assert_array_equal(cache.refcount[0], [2, 2] + [0] * 6)


def test_mount_truncation_wipes_unkept_pages():
    rng = np.random.default_rng(2)
    cache = _prefilled(rng, B=1, length=8)
    cache = _lane_op(cache, 0, pool.OP_INCREF, 0, 2)
    cache = _lane_op(cache, 0, pool.OP_RELEASE)
    cache = _lane_op(cache, 0, pool.OP_MOUNT, 4)     # keep 1 of 2 pages
    np.testing.assert_array_equal(cache.refcount[0], [2] + [0] * 7)
    assert int(cache.page_len[0, 1]) == 0
    assert int(cache.page_pos[0, 1]) == -1
    assert int(cache.cur_len[0]) == 4


def test_transitions_broadcast_over_stacked_leaves():
    rng = np.random.default_rng(3)
    cache = _prefilled(rng, B=2, length=8)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), cache)
    out = _lane_op(stacked, 0, pool.OP_INCREF, 0, 2)
    flat = _lane_op(cache, 0, pool.OP_INCREF, 0, 2)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(flat)):
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want))


def test_clone_prefix_copies_src_and_leaves_it_untouched():
    rng = np.random.default_rng(4)
    cache = _prefilled(rng, B=2, length=8)
    cache, _ = _append(cache, rng, lanes=[1])        # dirty the dst lane
    src_before = jax.tree.map(lambda x: np.asarray(x[0]), cache)

    out = pool.clone_prefix(cache, jnp.int32(0), jnp.int32(1),
                            jnp.int32(8))
    # dst's first 2 slots == src's, on every per-slot field (the slot
    # axis sits right after KV for the 4d/5d leaves, first for 2d ones)
    slot_prefix = dict(k_pages=np.s_[:, :2], v_pages=np.s_[:, :2],
                       rep_min=np.s_[:, :2], rep_max=np.s_[:, :2],
                       priority=np.s_[:2], page_pos=np.s_[:2],
                       page_len=np.s_[:2], pinned=np.s_[:2])
    for name, sl in slot_prefix.items():
        got = np.asarray(getattr(out, name)[1])[sl]
        want = np.asarray(getattr(out, name)[0])[sl]
        np.testing.assert_array_equal(got, want, err_msg=name)
    # src lane is bit-exactly what it was
    for name, want in src_before._asdict().items():
        np.testing.assert_array_equal(np.asarray(getattr(out, name)[0]),
                                      want, err_msg=name)
    # dst owns a private copy: one claim, clean tail, fresh lane state
    np.testing.assert_array_equal(out.refcount[1], [1, 1] + [0] * 6)
    np.testing.assert_array_equal(out.page_pos[1, 2:], -1)
    assert int(out.cur_len[1]) == 8
    assert int(out.active_slot[1]) == -1


# ---------------------------------------------------------------------------
# eviction + COW honor shared slots
# ---------------------------------------------------------------------------
def test_eviction_skips_shared_slots():
    """The argmin-priority victim must never be a ``refcount > 1`` slot,
    even when it has strictly the lowest priority."""
    rng = np.random.default_rng(5)
    cache = _prefilled(rng, B=1, length=4)           # slot 0 pinned
    for _ in range(8):                               # fill slots 1, 2
        cache, _ = _append(cache, rng)
    # share slot 1 (a full, unpinned decode page with lowest priority)
    cache = _lane_op(cache, 0, pool.OP_INCREF, 1, 2)
    cache = cache._replace(
        priority=cache.priority.at[0, 1].set(-100.0))
    assert int(cache.refcount[0, 1]) == 2
    shared_k = np.asarray(cache.k_pages[0, :, 1])

    evicted_slots = []
    for _ in range(3 * S):                           # overflow capacity
        cache, ev = _append(cache, rng)
        evicted_slots.append(int(ev[0]))
    assert any(e >= 0 for e in evicted_slots), "no eviction exercised"
    assert 1 not in evicted_slots
    np.testing.assert_array_equal(np.asarray(cache.k_pages[0, :, 1]),
                                  shared_k)
    assert int(cache.page_len[0, 1]) == P


def test_cow_diverts_append_and_matches_unshared_control():
    """Lanes 0 and 1 hold identical KV; lane 0's active page is shared.
    Appending the same token to both must (a) leave the shared page
    bit-exact, (b) produce a private copy on lane 0 whose bytes equal
    lane 1's in-place page — the unshared control."""
    rng = np.random.default_rng(6)
    cache = _prefilled(rng, B=2, length=4)
    # two decode tokens -> both lanes have active slot 1, page_len 2
    kv = [(rng.standard_normal((KV, HD)).astype(np.float32),
           rng.standard_normal((KV, HD)).astype(np.float32))
          for _ in range(3)]
    for k1, v1 in kv[:2]:
        k = jnp.asarray(np.stack([k1, k1]))
        v = jnp.asarray(np.stack([v1, v1]))
        cache, _ = pc.append_token(cache, k, v,
                                   cache.cur_len.astype(jnp.float32))
    assert int(cache.active_slot[0]) == int(cache.active_slot[1]) == 1
    cache = _lane_op(cache, 0, pool.OP_INCREF, 1, 2)  # share lane 0's
    shared_before = np.asarray(cache.k_pages[0, :, 1])

    k3, v3 = kv[2]
    cache, ev = pc.append_token(cache, jnp.asarray(np.stack([k3, k3])),
                                jnp.asarray(np.stack([v3, v3])),
                                cache.cur_len.astype(jnp.float32))
    s0, s1 = int(cache.active_slot[0]), int(cache.active_slot[1])
    assert s0 != 1, "COW did not divert the append"
    assert s1 == 1, "control lane should append in place"
    # shared page untouched, lane's claim moved off it
    np.testing.assert_array_equal(np.asarray(cache.k_pages[0, :, 1]),
                                  shared_before)
    assert int(cache.refcount[0, 1]) == 1
    assert int(cache.refcount[0, s0]) == 1
    # byte parity with the unshared control lane
    np.testing.assert_array_equal(np.asarray(cache.k_pages[0, :, s0]),
                                  np.asarray(cache.k_pages[1, :, s1]))
    np.testing.assert_array_equal(np.asarray(cache.v_pages[0, :, s0]),
                                  np.asarray(cache.v_pages[1, :, s1]))
    for name in ("page_pos", "page_len", "priority", "pinned"):
        assert np.asarray(getattr(cache, name))[0, s0] \
            == np.asarray(getattr(cache, name))[1, s1], name
    assert int(cache.cur_len[0]) == int(cache.cur_len[1]) == 7


# ---------------------------------------------------------------------------
# satellite: over-capacity ingest stays accounted
# ---------------------------------------------------------------------------
def test_overflow_ingest_clips_cur_len_with_tokens_cached():
    """A chunk larger than the remaining capacity drops the overflow
    pages entirely — ``cur_len == tokens_cached()`` still holds, and no
    resident page is clobbered by a duplicate scatter index."""
    rng = np.random.default_rng(7)
    cache = _prefilled(rng, B=1, length=24)          # 6 of 8 slots
    k = rng.standard_normal((1, 16, KV, HD)).astype(np.float32)
    v = rng.standard_normal((1, 16, KV, HD)).astype(np.float32)
    out = pc.ingest_prefill_chunk(cache, jnp.asarray(k), jnp.asarray(v),
                                  jnp.asarray([16], jnp.int32))
    assert int(out.cur_len[0]) == 32                 # 24 + 2 pages fit
    assert int(out.tokens_cached()[0]) == int(out.cur_len[0])
    # the last resident slot holds the page that belongs there, not the
    # clipped overflow
    np.testing.assert_array_equal(
        np.asarray(out.k_pages[0, :, 7]),
        np.asarray(k[0, 4:8].transpose(1, 0, 2)))


def test_ingest_refuses_to_overwrite_shared_slots():
    rng = np.random.default_rng(8)
    cache = _prefilled(rng, B=1, length=4)
    cache = _lane_op(cache, 0, pool.OP_INCREF, 0, 1)
    cache = _lane_op(cache, 0, pool.OP_RELEASE)      # parked page, rc=1
    cache = _lane_op(cache, 0, pool.OP_INCREF, 0, 1)  # second claim
    shared_k = np.asarray(cache.k_pages[0, :, 0])
    k = rng.standard_normal((1, 4, KV, HD)).astype(np.float32)
    v = rng.standard_normal((1, 4, KV, HD)).astype(np.float32)
    out = pc.ingest_prefill_chunk(cache, jnp.asarray(k), jnp.asarray(v),
                                  jnp.asarray([4], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out.k_pages[0, :, 0]),
                                  shared_k)
    assert int(out.cur_len[0]) == 0                  # write was dropped


# ---------------------------------------------------------------------------
# the pool property: shared slots are bit-frozen
# ---------------------------------------------------------------------------
def _shared_snapshot(cache):
    """{(lane, slot): (k, v, pos, len)} for every refcount > 1 slot."""
    rc = np.asarray(cache.refcount)
    out = {}
    for b, s in zip(*np.nonzero(rc > 1)):
        out[(b, s)] = (np.asarray(cache.k_pages[b, :, s]),
                       np.asarray(cache.v_pages[b, :, s]),
                       int(cache.page_pos[b, s]),
                       int(cache.page_len[b, s]))
    return out


def _check_shared_frozen(before, cache, ctx):
    after = _shared_snapshot(cache)
    for key, (k0, v0, pos0, len0) in before.items():
        if key not in after:
            continue                  # claims legitimately dropped
        k1, v1, pos1, len1 = after[key]
        np.testing.assert_array_equal(k1, k0, err_msg=f"{ctx} K {key}")
        np.testing.assert_array_equal(v1, v0, err_msg=f"{ctx} V {key}")
        assert (pos1, len1) == (pos0, len0), f"{ctx} meta {key}"


def _pool_walk(seed):
    """Protocol-legal random walk over a 2-lane cache.

    Per lane: run (appends; sometimes an INCREF pins the active page,
    so later appends exercise COW) -> park (INCREF full pages, then
    RELEASE) -> resume (MOUNT a page-aligned prefix) or recycle (drop
    claims host-side, RESET).  After every step, every slot that was
    and still is shared must be bit-identical.
    """
    rng = np.random.default_rng(seed)
    cache = _prefilled(rng, B=2, length=int(rng.integers(1, 3)) * P)
    running = [True, True]
    parked_pages = [0, 0]
    for step in range(40):
        lane = int(rng.integers(0, 2))
        before = _shared_snapshot(cache)
        roll = rng.random()
        if running[lane]:
            if roll < 0.55:
                cache, ev = _append(cache, rng, lanes=[lane])
                for (b, s) in before:
                    assert not (b == lane and s == int(ev[lane])), \
                        f"seed {seed} step {step}: evicted shared slot"
            elif roll < 0.7 and int(cache.active_slot[lane]) >= 0 \
                    and int(cache.refcount[
                        lane, int(cache.active_slot[lane])]) < 3:
                a = int(cache.active_slot[lane])
                cache = _lane_op(cache, lane, pool.OP_INCREF, a, a + 1)
            else:
                full = int(cache.cur_len[lane]) // P
                if full:
                    cache = _lane_op(cache, lane, pool.OP_INCREF, 0,
                                     full)
                cache = _lane_op(cache, lane, pool.OP_RELEASE)
                running[lane] = False
                parked_pages[lane] = full
        else:
            if roll < 0.5 and parked_pages[lane]:
                keep = int(rng.integers(1, parked_pages[lane] + 1))
                cache = _lane_op(cache, lane, pool.OP_MOUNT, keep * P)
                running[lane] = True
                parked_pages[lane] = keep
            else:
                # recycling drops the host-side claims first, exactly
                # like Engine._drop_parked + OP_RESET
                cache = _lane_op(cache, lane, pool.OP_RESET)
                running[lane] = True
                parked_pages[lane] = 0
        _check_shared_frozen(before, cache,
                             f"seed {seed} step {step}")
        rc = np.asarray(cache.refcount)
        assert (rc >= 0).all(), rc
        # free slots never carry claims; claimed slots are never free
        free = np.asarray(cache.page_pos) < 0
        assert (rc[free] == 0).all(), (rc, free)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 16))
def test_pool_shared_slots_frozen_property(seed):
    _pool_walk(seed)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pool_shared_slots_frozen_deterministic(seed):
    _pool_walk(seed)


# ---------------------------------------------------------------------------
# prefix index + session ids (host half)
# ---------------------------------------------------------------------------
def test_prefix_index_register_lookup():
    idx = pool.PrefixIndex(P)
    toks = np.arange(12, dtype=np.int32)
    assert idx.register(0, toks) == 3
    assert idx.covered_pages(0) == 3
    assert idx.lookup(np.concatenate([toks, [99]])) == (0, 3)
    assert idx.lookup(toks[:8]) == (0, 2)
    assert idx.lookup(toks[:7]) == (0, 1)            # one full page
    assert idx.lookup(toks[:3]) is None              # below a page
    other = toks.copy()
    other[0] = 77
    assert idx.lookup(other) is None
    # content is canonical: a second lane registering the same prefix
    # gains no cover (one copy of the bytes is enough)
    assert idx.register(1, toks) == 0
    assert idx.covered_pages(1) == 0


def test_prefix_index_truncate_and_drop():
    idx = pool.PrefixIndex(P)
    toks = np.arange(12, dtype=np.int32)
    idx.register(0, toks)
    idx.truncate(0, 1)
    assert idx.covered_pages(0) == 1
    assert idx.lookup(toks) == (0, 1)
    idx.drop_lane(0)
    assert idx.covered_pages(0) == 0
    assert idx.lookup(toks) is None
    # dropped digests are claimable again
    assert idx.register(1, toks) == 3
    assert idx.lookup(toks) == (1, 3)


def test_session_id_contract():
    sid = pool.generate_session_id()
    assert pool.validate_session_id(sid) == sid
    for bad in ("", "xyz", "A" * 32, "g" * 32, 123, None,
                pool.generate_session_id() + "0"):
        with pytest.raises(ValueError):
            pool.validate_session_id(bad)
