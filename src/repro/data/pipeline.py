"""Synthetic reasoning data pipeline (tokenizer-free, verifiable).

The paper evaluates on GSM8k / MATH500 / AIME — short question, long
chain-of-thought answer.  On an offline CPU box we reproduce the
*shape* of that workload with a synthetic arithmetic-CoT corpus whose
answers are machine-verifiable, so the accuracy benchmarks (paper
Fig. 6 proxy) measure real end-to-end reasoning degradation under each
sparsity policy.

Grammar (token ids are vocab-parametric; layout mirrors "short prefill,
long decode"):

  prompt:  Q a0 <op1> a1 ; x0 = <v0> EOSQ          (the "question")
  chain:   x1 = x0 <op> c1 -> <v1> ; x2 = ...      (the "reasoning")
  answer:  A <final-value> EOS

Values are held in [0, modulus); each CoT step applies +/- a small
constant, so every intermediate "lemma" x_i is needed exactly once to
produce x_{i+1} — a structural analogue of the paper's milestone
tokens.  Sequences are deterministic per (seed, index).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    prompt_len: int = 16          # short prefill, like the paper's tasks
    chain_steps: int = 24         # CoT length knob
    modulus: int = 97             # value range
    seed: int = 0

    def __post_init__(self):
        assert self.vocab_size >= self.modulus + 16, "need room for specials"


# special tokens live above the value range
def specials(cfg: DataConfig) -> Dict[str, int]:
    m = cfg.modulus
    return {
        "PAD": m + 0, "Q": m + 1, "EOSQ": m + 2, "STEP": m + 3,
        "ARROW": m + 4, "ADD": m + 5, "SUB": m + 6, "A": m + 7,
        "EOS": m + 8,
    }


def chain_step(v: int, m: int) -> Tuple[int, int, int]:
    """Deterministic transition: (op, c, v_next) as a pure function of
    the current value.  The whole chain — and hence the final answer —
    is determined by the prompt's start value, so greedy free-running
    decode is exactly verifiable (a model that has learnt the rule must
    reproduce the gold chain)."""
    op = (v * 7 + 3) % 2
    c = (v * 5 + 1) % 12 + 1
    v_next = (v + c) % m if op == 0 else (v - c) % m
    return op, c, v_next


def make_example(cfg: DataConfig, index: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Returns (tokens [seq_len], loss_mask [seq_len], final_answer)."""
    sp = specials(cfg)
    rng = np.random.default_rng((cfg.seed << 20) ^ index)
    m = cfg.modulus

    v = int(rng.integers(0, m))
    toks = [sp["Q"], v, sp["EOSQ"]]
    prompt_end = len(toks)
    for _ in range(cfg.chain_steps):
        op, c, v_new = chain_step(v, m)
        toks += [sp["STEP"], sp["ADD"] if op == 0 else sp["SUB"],
                 c, sp["ARROW"], v_new]
        v = v_new
    toks += [sp["A"], v, sp["EOS"]]

    toks = toks[:cfg.seq_len]
    mask = np.zeros(cfg.seq_len, np.float32)
    mask[prompt_end - 1:len(toks) - 1] = 1.0   # predict CoT + answer
    out = np.full(cfg.seq_len, sp["PAD"], np.int32)
    out[:len(toks)] = toks
    return out, mask, v


def batches(cfg: DataConfig, batch_size: int,
            start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite deterministic batch stream."""
    i = start
    while True:
        toks = np.zeros((batch_size, cfg.seq_len), np.int32)
        mask = np.zeros((batch_size, cfg.seq_len), np.float32)
        ans = np.zeros((batch_size,), np.int32)
        for b in range(batch_size):
            toks[b], mask[b], ans[b] = make_example(cfg, i + b)
        i += batch_size
        yield {"tokens": toks, "loss_mask": mask, "answer": ans,
               "index": np.arange(i - batch_size, i)}


def prompt_of(cfg: DataConfig, index: int) -> Tuple[np.ndarray, int]:
    """The question-only prefix (for serving evals) and its length."""
    toks, _, _ = make_example(cfg, index)
    sp = specials(cfg)
    end = int(np.argmax(toks == sp["EOSQ"])) + 1
    return toks[:end], end


def verify_answer(cfg: DataConfig, index: int, decoded: np.ndarray) -> bool:
    """Exact-match check: does the decoded stream contain `A <v> EOS`?"""
    _, _, gold = make_example(cfg, index)
    sp = specials(cfg)
    dec = list(np.asarray(decoded).ravel())
    for j in range(len(dec) - 1):
        if dec[j] == sp["A"]:
            return dec[j + 1] == gold
    return False
