"""KV-sparsity policies: RaaS (the paper), Quest, H2O, StreamingLLM, Dense.

All five are expressed over the same :class:`PagedCache` by varying
three hooks:

  * ``cache_slots(cfg, max_seq)``   — how much memory the policy needs
    (this IS the paper's O(L)-vs-O(N) distinction, made structural);
  * ``select(cache, scores, cfg)``  — which pages the decode attention
    may touch this step (Quest's top-k; everyone else: all live pages);
  * ``refresh(cache, scores, page_probs, cfg)`` — how eviction priority
    evolves (RaaS timestamps, H2O accumulation, Streaming: frozen).

Paper mapping (§3.2):
  RaaS      priority = timestamp of last step whose *estimated* page
            score passed the alpha/top-r rule; evict argmin; prefill
            pinned.  O(L) slots.
  Streaming priority = arrival order, never refreshed -> sliding window
            + pinned prefill (sink).  O(L) slots.
  H2O       priority = accumulated true attention mass; recent window
            protected.  O(L) slots, page_size=1 recommended (token
            granularity, as in the paper's description).
  Quest     O(N) slots, never evicts; top-k pages by estimated score
            are attended each step.  O(L) time, O(N) memory.
  Dense     O(N) slots, attends everything.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RaasConfig
from repro.core.paged_cache import PagedCache, INF

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Capacity: the O(L) vs O(N) axis.
# ---------------------------------------------------------------------------
def cache_slots(cfg: RaasConfig, max_seq_len: int, prefill_len: int = 0) -> int:
    """Number of cache slots the policy requires for ``max_seq_len``."""
    P = cfg.page_size
    if cfg.policy in ("dense", "quest"):
        # +1: prefill never shares a page with decode, so a partial
        # prefill tail page costs one extra slot.
        return -(-max_seq_len // P) + 1                  # O(N)
    budget_pages = cfg.budget_tokens // P
    pre_pages = -(-prefill_len // P)
    if cfg.policy in ("raas", "streaming", "h2o"):
        # paper: budget includes pinned prefill; guarantee at least one
        # decode page so generation can proceed.
        return max(budget_pages, pre_pages + 1)          # O(L)
    if cfg.policy == "quest_raas":
        # hybrid (paper §Limitations recommendation): prefill pages are
        # all *retained* (Quest-selected at attention time), decode
        # pages get the RaaS budget -> O(N_prefill + L) memory,
        # O(k + L) attention time.
        return pre_pages + budget_pages
    raise ValueError(cfg.policy)


# ---------------------------------------------------------------------------
# RaaS timestamp-refresh rule (paper §3.2, "The Choice of alpha").
# ---------------------------------------------------------------------------
def raas_selected_mask(scores: jnp.ndarray, valid: jnp.ndarray,
                       cfg: RaasConfig) -> jnp.ndarray:
    """[B, S] bool — pages whose timestamp refreshes this step.

    ``scores`` are logit-scale estimated page scores (-inf at invalid).
    ``use_top_r``: refresh the ceil(r * n_valid) highest-scoring pages
    (the paper's recommended r = 50% rule).  Otherwise: refresh pages
    whose softmax probability exceeds alpha.
    """
    if cfg.use_top_r:
        # rank pages descending by score; rank < ceil(r * n_valid)
        order = jnp.argsort(-scores, axis=1)
        ranks = jnp.argsort(order, axis=1)               # rank of each slot
        n_valid = valid.sum(axis=1, keepdims=True)
        cutoff = jnp.ceil(cfg.top_r * n_valid).astype(jnp.int32)
        return (ranks < cutoff) & valid
    # alpha rule on estimated softmax probabilities
    m = jnp.max(jnp.where(valid, scores, _NEG_INF), axis=1, keepdims=True)
    e = jnp.where(valid, jnp.exp(scores - m), 0.0)
    probs = e / jnp.maximum(e.sum(axis=1, keepdims=True), 1e-30)
    return (probs > cfg.alpha) & valid


# ---------------------------------------------------------------------------
# Selection: which pages this step's attention touches.
# ---------------------------------------------------------------------------
def select_pages(cache: PagedCache, scores: jnp.ndarray,
                 cfg: RaasConfig) -> Optional[jnp.ndarray]:
    """Return gather indices [B, K] for Quest-style policies, or
    None = attend the whole live cache."""
    B, S = scores.shape
    barange = jnp.arange(B)
    if cfg.policy == "quest":
        k = min(cfg.quest_topk_pages, S)
        # always include the active page (recent tokens), Quest-style.
        active = jnp.where(cache.active_slot >= 0, cache.active_slot, 0)
        boosted = scores.at[barange, active].set(INF)
        _, idx = jax.lax.top_k(boosted, k)
        return idx.astype(jnp.int32)
    if cfg.policy == "quest_raas":
        # top-k among the (static) prefill slot range + every decode
        # slot.  Slot layout guarantees prefill occupies [0, n_pre).
        n_pre = cfg.prefill_pages_hint
        if n_pre == 0 or n_pre >= S:
            return None
        k = min(cfg.quest_topk_pages, n_pre)
        _, idx = jax.lax.top_k(scores[:, :n_pre], k)
        decode_idx = jnp.broadcast_to(jnp.arange(n_pre, S), (B, S - n_pre))
        return jnp.concatenate([idx, decode_idx], axis=1).astype(jnp.int32)
    return None


# ---------------------------------------------------------------------------
# Refresh: eviction-priority dynamics.
# ---------------------------------------------------------------------------
def refresh_priority(cache: PagedCache, scores: jnp.ndarray,
                     page_probs: jnp.ndarray, cfg: RaasConfig) -> PagedCache:
    """Update per-page priorities after a decode step.

    ``scores``: estimated page scores [B, S] (rep-key based, logit
    scale).  ``page_probs``: true attention probability mass per page
    [B, S] (from the attention kernel; H2O's signal).
    """
    valid = cache.valid_pages()
    if cfg.policy in ("raas", "quest_raas"):
        sel = raas_selected_mask(scores, valid, cfg)
        now = cache.cur_len.astype(jnp.float32)[:, None]
        return cache._replace(
            priority=jnp.where(sel, now, cache.priority))
    if cfg.policy == "h2o":
        return cache._replace(
            priority=cache.priority + jnp.where(valid, page_probs, 0.0))
    # streaming / dense / quest: priorities are static (arrival order /
    # unused).
    return cache


def new_page_priority(cache: PagedCache, cfg: RaasConfig) -> jnp.ndarray:
    """[B] f32 priority for a freshly allocated page."""
    now = cache.cur_len.astype(jnp.float32)
    if cfg.policy == "h2o":
        return jnp.zeros_like(now)       # protected by the recent window
    return now                           # raas timestamp / arrival order


def protect_recent_tokens(cfg: RaasConfig) -> int:
    if cfg.policy == "h2o":
        return cfg.h2o_recent
    return 0


def sink_pin_below(cache_has_prefill: bool, cfg: RaasConfig) -> int:
    """StreamingLLM pins sink tokens when there is no pinned prefill."""
    if cfg.policy == "streaming" and not cache_has_prefill:
        return cfg.sink_tokens
    return 0


class PolicyStats(NamedTuple):
    """Per-step observability (benchmarks/Fig-proxies consume this)."""

    evicted_slot: jnp.ndarray       # [B] i32, -1 = none
    pages_attended: jnp.ndarray     # [B] i32
    tokens_cached: jnp.ndarray      # [B] i32
