"""The paper's primary contribution: RaaS KV-cache sparsity.

paged_cache.py — slot-based fixed-capacity paged KV cache (O(L))
policies.py    — raas | quest | h2o | streaming | dense | quest_raas
attention.py   — policy-aware decode attention step (append / score /
                 select / attend / refresh), one fused jittable fn
"""
