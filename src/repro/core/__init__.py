"""The paper's primary contribution: RaaS KV-cache sparsity.

paged_cache.py — slot-based fixed-capacity paged KV cache (O(L))
policy_base.py — SparsityPolicy interface + decorator registry
policies/      — one file per policy: raas | quest | h2o | streaming |
                 dense | quest_raas (drop a file in to add one)
attention.py   — policy-aware decode attention step (append / score /
                 select / attend / refresh), one fused jittable fn
"""
