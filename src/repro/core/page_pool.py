"""Refcounted page pool over :class:`~repro.core.paged_cache.PagedCache`:
prefix caching and multi-turn KV sessions via slot aliasing.

The paged cache is lane-major: slots belong to a lane for the lane's
whole lifetime, and the zero-copy kernels resolve pages through
scalar-prefetched index tables — so *aliasing* a page never moves KV
bytes, it only changes who is accounted as needing them.  This module
owns that accounting, in two halves:

**Device half** — :func:`transition_lanes` applies one batched lane
transition per dispatch (op codes below) and :func:`clone_prefix`
copies one lane's leading prefix pages into another lane (the only KV
byte traffic in the pool, used when a busy donor's prefix is wanted on
a second lane).  :func:`restore_lane` writes a checkpointed lane's
rows (from :func:`~repro.core.paged_cache.snapshot_lane`) onto any
free lane, re-stamping the refcount to the restoring request's single
claim — the device half of lane preemption (serving/resilience.py).
All are pure jittable functions over a single
``PagedCache`` whose leaves may be period-stacked (``[n_periods, B,
...]``) — every mask broadcasts right-aligned, exactly like
:func:`~repro.core.paged_cache.reset_lanes`.

**Host half** — :class:`PrefixIndex` is a chained-hash index over
page-aligned prompt prefixes: ``register`` records a lane's parked
prefix at every full-page depth, ``lookup`` returns the deepest
registered prefix matching a new prompt (hash-chain walk + explicit
token validation, so a hash collision can never alias wrong bytes).
:func:`generate_session_id` / :func:`validate_session_id` are the
multi-turn front-end contract: a client keeps one id per conversation,
and a follow-up request carrying it resumes the parked lane instead of
re-prefilling the whole conversation.

Refcount protocol (see the paged-cache module docstring): a slot's
``refcount`` is the number of independent claims on its contents —
the running request holds one on every slot it writes or mounts, and
the index holds one on every slot some registered prefix needs.  The
engine drives transitions::

    admit (no match)        RESET       wipe the lane, refcount included
    admit (parked donor)    MOUNT a0    keep the first ceil(a0/P) slots,
                                        +1 request claim on them, wipe
                                        the rest; cur_len = a0
    prefill done / parked   INCREF a0 a1  +1 index claim on slots [a0, a1)
    request finished        RELEASE     -1 on every claimed slot; slots
                                        reaching 0 are wiped, slots the
                                        index still claims stay *parked*

``refcount`` mutation is confined to this module and ``paged_cache``
(the ``pool-refcount-outside-pool`` lint rule): the engine reasons in
lane transitions, never raw counts.
"""
from __future__ import annotations

import hashlib
import re
import uuid
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_cache import AFTER_LANE, INF, PagedCache, lane_axis

# lane transition op codes (device-side; one per lane per dispatch)
OP_NOP = 0
OP_RESET = 1
OP_RELEASE = 2
OP_MOUNT = 3
OP_INCREF = 4
OP_NAMES = ("nop", "reset", "release", "mount", "incref")


def _meta2d(x: jnp.ndarray) -> jnp.ndarray:
    """One ``[B, S]`` view of possibly period-stacked slot metadata.

    Slot metadata evolves identically across stacked layers (every
    ingest/append applies the same masks to every layer), so layer 0's
    copy is authoritative for building lane/slot masks that must
    broadcast against leaves of *different* ranks.
    """
    return x[(0,) * (x.ndim - 2)]


def transition_lanes(cache: PagedCache, op: jnp.ndarray, a0: jnp.ndarray,
                     a1: jnp.ndarray) -> PagedCache:
    """Apply one pool transition per lane (``op``/``a0``/``a1``: [B]
    i32), entirely on device and metadata-only — K/V pages are never
    touched (a wiped slot's bytes are dead via ``page_len == 0``).

    Per lane: NOP leaves everything; RESET wipes the lane including
    ``refcount``; RELEASE drops one claim from every claimed slot and
    wipes slots reaching zero (the rest stay parked); MOUNT keeps the
    first ``ceil(a0 / P)`` slots (+1 claim — the mounting request),
    wipes the rest and sets ``cur_len = a0``; INCREF adds one claim on
    slots ``[a0, a1)`` (prefix registration).  The caller queues at
    most one op per lane per dispatch and owns the host-side ordering.
    """
    S = cache.page_len.shape[-1]
    P = cache.k_pages.shape[-2]
    rc2 = _meta2d(cache.refcount)                            # [B, S]
    slot_ids = jnp.arange(S)[None]                           # [1, S]

    is_reset = op == OP_RESET
    is_release = op == OP_RELEASE
    is_mount = op == OP_MOUNT
    is_incref = op == OP_INCREF

    kept_pages = -(-a0 // P)                                 # [B]
    kept = slot_ids < kept_pages[:, None]                    # [B, S]
    claimed = rc2 > 0
    dec = (is_release[:, None] & claimed).astype(jnp.int32)
    inc = ((is_mount[:, None] & kept & claimed)
           | (is_incref[:, None] & claimed
              & (slot_ids >= a0[:, None])
              & (slot_ids < a1[:, None]))).astype(jnp.int32)
    zero = is_reset[:, None] | (is_mount[:, None] & ~kept)
    rc2_new = jnp.where(zero, 0, rc2 - dec + inc)

    # slots this transition frees: metadata is wiped so they read as
    # free pages everywhere (eviction, kernels, accounting alike)
    clear = (is_reset[:, None]
             | (is_release[:, None] & (rc2_new == 0))
             | (is_mount[:, None] & ~kept))                  # [B, S]
    c3 = clear[:, None, :, None]                # vs [.., B, KV, S, hd]
    lane = is_reset | is_release | is_mount                  # [B]
    # mounted pages become the new request's prompt prefix: pin them
    # and restore the prefill priority (= first-token position), so a
    # mounted lane is byte-identical to one that re-ran prefill — the
    # parity the session/prefix tests assert.
    mountk = is_mount[:, None] & kept & claimed
    return cache._replace(
        priority=jnp.where(clear, 0.0,
                           jnp.where(mountk,
                                     cache.page_pos.astype(jnp.float32),
                                     cache.priority)),
        page_pos=jnp.where(clear, -1, cache.page_pos),
        page_len=jnp.where(clear, 0, cache.page_len),
        pinned=jnp.where(clear, False, cache.pinned | mountk),
        refcount=jnp.where(zero, 0, cache.refcount - dec + inc),
        rep_min=jnp.where(c3, INF, cache.rep_min),
        rep_max=jnp.where(c3, -INF, cache.rep_max),
        active_slot=jnp.where(lane, -1, cache.active_slot),
        cur_len=jnp.where(lane, jnp.where(is_mount, a0, 0),
                          cache.cur_len),
    )


# lane-axis layout lives with the cache (paged_cache.AFTER_LANE);
# kept under the old name for the take/put helpers below.
_AFTER_LANE = AFTER_LANE


def clone_prefix(cache: PagedCache, src: jnp.ndarray, dst: jnp.ndarray,
                 keep_tokens: jnp.ndarray) -> PagedCache:
    """Copy lane ``src``'s first ``ceil(keep_tokens / P)`` prefix slots
    into lane ``dst`` — the busy-donor path: ``src`` keeps serving
    untouched while ``dst`` starts from a private, byte-identical copy
    of the shared prefix (``refcount = 1``: the new request's claim
    only; the index keeps pointing at the donor).  ``dst``'s other
    slots are wiped; ``cur_len`` becomes ``keep_tokens``.

    O(prefix bytes) device traffic for one lane — the only KV copy in
    the pool, and still far cheaper than re-running prefill compute.
    """
    S = cache.page_len.shape[-1]
    P = cache.k_pages.shape[-2]
    kept = jnp.arange(S) < -(-keep_tokens // P)              # [S]

    def take(name):
        x = getattr(cache, name)
        ax = x.ndim - 1 - _AFTER_LANE[name]
        return jax.lax.dynamic_index_in_dim(x, src, axis=ax,
                                            keepdims=False)

    def put(name, row):
        x = getattr(cache, name)
        ax = x.ndim - 1 - _AFTER_LANE[name]
        return jax.lax.dynamic_update_index_in_dim(
            x, row.astype(x.dtype), dst, axis=ax)

    def take_at(name, lane):
        x = getattr(cache, name)
        ax = x.ndim - 1 - _AFTER_LANE[name]
        return jax.lax.dynamic_index_in_dim(x, lane, axis=ax,
                                            keepdims=False)

    # kv rows [.., KV, S, P, hd]: the [S, 1, 1] mask right-aligns onto
    # the slot axis; non-kept slots keep dst's (dead) bytes in place.
    kv_keep = kept[:, None, None]
    k_row = jnp.where(kv_keep, take("k_pages"), take_at("k_pages", dst))
    v_row = jnp.where(kv_keep, take("v_pages"), take_at("v_pages", dst))
    new = cache._replace(
        k_pages=put("k_pages", k_row),
        v_pages=put("v_pages", v_row),
        rep_min=put("rep_min", jnp.where(kept[:, None],
                                         take("rep_min"), INF)),
        rep_max=put("rep_max", jnp.where(kept[:, None],
                                         take("rep_max"), -INF)),
        priority=put("priority", jnp.where(kept, take("priority"), 0.0)),
        page_pos=put("page_pos", jnp.where(kept, take("page_pos"), -1)),
        page_len=put("page_len", jnp.where(kept, take("page_len"), 0)),
        pinned=put("pinned", jnp.where(kept, take("pinned"), False)),
        refcount=put("refcount",
                     jnp.broadcast_to(kept.astype(jnp.int32),
                                      jnp.shape(take("refcount")))),
        active_slot=put("active_slot",
                        jnp.full(jnp.shape(take("active_slot")), -1,
                                 jnp.int32)),
        cur_len=put("cur_len",
                    jnp.broadcast_to(keep_tokens,
                                     jnp.shape(take("cur_len")))),
    )
    return new


def restore_lane(cache: PagedCache, lane: jnp.ndarray,
                 snap: PagedCache) -> PagedCache:
    """Write a checkpointed lane (``snap``: per-lane rows from
    :func:`~repro.core.paged_cache.snapshot_lane`, possibly round-
    tripped through host memory) into lane ``lane`` of ``cache``.

    Every leaf row is overwritten, so the target lane may hold
    anything (the engine drops parked claims on it first).  The
    restored ``refcount`` is re-stamped to exactly one claim — the
    restoring request's — on every live slot: the snapshot's counts
    included index claims of the *source* lane, which stayed behind
    (parked) when the checkpoint released it.  Byte parity of decode
    is unaffected: refcounts only gate eviction/overwrite protection,
    and every slot whose count could exceed one is a pinned prefill /
    mounted page that is protected regardless.
    """
    rows = snap._replace(
        refcount=(snap.page_len > 0).astype(jnp.int32))

    def put(name: str) -> jnp.ndarray:
        x = getattr(cache, name)
        row = jnp.asarray(getattr(rows, name)).astype(x.dtype)
        return jax.lax.dynamic_update_index_in_dim(
            x, row, lane, axis=lane_axis(x, name))

    return PagedCache(**{f: put(f) for f in PagedCache._fields})


# ---------------------------------------------------------------------------
# Host half: prefix index + session ids
# ---------------------------------------------------------------------------
class PrefixIndex:
    """Chained-hash index over page-aligned prompt prefixes, host-side.

    Each registered lane contributes one digest per full-page depth of
    its parked prefix; digests chain (depth ``d`` hashes depth ``d-1``'s
    state plus page ``d``'s tokens), so one walk over a new prompt's
    pages probes every depth.  Lookups validate the actual tokens
    against the registered lane's recorded prefix — a digest collision
    degrades to a miss, never to aliasing wrong KV bytes.

    The index is pure bookkeeping: it never touches device state.  The
    engine mirrors every ``register``/``truncate``/``drop_lane`` with
    the matching refcount transition (INCREF / MOUNT / RESET), keeping
    the invariant that a lane's parked pages ``[0, covered_pages)``
    hold exactly one index claim each.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self._entry: Dict[bytes, Tuple[int, int]] = {}  # digest -> (lane, depth)
        self._lane_tokens: Dict[int, np.ndarray] = {}   # lane -> covered prefix

    def _digests(self, tokens) -> Iterator[Tuple[int, bytes]]:
        toks = np.asarray(tokens, np.int32)
        P = self.page_size
        h = hashlib.sha256()
        for d in range(len(toks) // P):
            h.update(toks[d * P:(d + 1) * P].tobytes())
            yield d + 1, h.digest()

    def covered_pages(self, lane: int) -> int:
        """Pages of ``lane``'s parked prefix the index holds a claim on."""
        return len(self._lane_tokens.get(lane, ())) // self.page_size

    def register(self, lane: int, tokens) -> int:
        """Record ``lane``'s resident prefix (every full page of
        ``tokens``) and return the lane's new covered-page count.  The
        engine INCREFs slots ``[old_covered, new_covered)`` — the index's
        claim on the newly covered pages.  Depths whose digest another
        lane already owns are skipped (one canonical copy per content)."""
        prev = self.covered_pages(lane)
        new_cover = prev
        for d, dg in self._digests(tokens):
            owner = self._entry.get(dg)
            if owner is None:
                self._entry[dg] = (lane, d)
                new_cover = max(new_cover, d)
            elif owner[0] == lane:
                new_cover = max(new_cover, d)
        if new_cover > prev:
            self._lane_tokens[lane] = np.asarray(
                tokens, np.int32)[:new_cover * self.page_size].copy()
        return new_cover

    def lookup(self, tokens) -> Optional[Tuple[int, int]]:
        """Deepest registered prefix matching ``tokens``, as
        ``(lane, n_pages)``; None if nothing matches.  Token-validated:
        the match is only reported if the owning lane's recorded prefix
        is byte-equal to the prompt's leading pages."""
        toks = np.asarray(tokens, np.int32)
        P = self.page_size
        best = None
        for d, dg in self._digests(toks):
            owner = self._entry.get(dg)
            if owner is None:
                continue
            lane, depth = owner
            reg = self._lane_tokens.get(lane)
            if reg is None or len(reg) < depth * P:
                continue
            if not np.array_equal(reg[:d * P], toks[:d * P]):
                continue
            best = (lane, d)
        return best

    def truncate(self, lane: int, n_pages: int) -> None:
        """Shrink ``lane``'s registration to its first ``n_pages`` pages
        (a mount kept fewer pages than were parked).  The matching
        device-side wipe is MOUNT's own ``~kept`` clear."""
        reg = self._lane_tokens.get(lane)
        if reg is None:
            return
        for d, dg in self._digests(reg):
            if d > n_pages and self._entry.get(dg) == (lane, d):
                del self._entry[dg]
        if n_pages <= 0:
            self._lane_tokens.pop(lane, None)
        else:
            self._lane_tokens[lane] = reg[:n_pages * self.page_size]

    def drop_lane(self, lane: int) -> None:
        """Forget ``lane`` entirely (the engine is about to RESET it)."""
        reg = self._lane_tokens.pop(lane, None)
        if reg is None:
            return
        for d, dg in self._digests(reg):
            if self._entry.get(dg) == (lane, d):
                del self._entry[dg]


_SESSION_RE = re.compile(r"^[0-9a-f]{32}$")


def generate_session_id() -> str:
    """New conversation id for the multi-turn session front-end: the
    client keeps one per conversation and sends it on every turn."""
    return uuid.uuid4().hex


def validate_session_id(session_id: str) -> str:
    """Validate a client-supplied session id (shape only — whether the
    engine still holds the session's KV is the engine's business).
    Returns the id; raises ``ValueError`` on malformed input."""
    if not isinstance(session_id, str) or not _SESSION_RE.match(session_id):
        raise ValueError(
            f"malformed session id {session_id!r}: expected a 32-char "
            "lowercase hex string from generate_session_id()")
    return session_id
