"""Convenience facade for the RaaS algorithm (paper sections 3.2-3.3).

The implementation is split across paged_cache (memory substrate),
policies (timestamp/eviction semantics) and attention (the fused decode
step); this module re-exports the public surface under one name.
"""
from repro.config import RaasConfig
from repro.core.attention import decode_attend
from repro.core.paged_cache import CacheSpec, PagedCache, init_cache, ingest_prefill
from repro.core.policies import cache_slots, raas_selected_mask
from repro.core.policy_base import (SparsityPolicy, available_policies,
                                    get_policy, register_policy)

__all__ = [
    "RaasConfig", "decode_attend", "CacheSpec", "PagedCache",
    "init_cache", "ingest_prefill", "cache_slots", "raas_selected_mask",
    "SparsityPolicy", "available_policies", "get_policy", "register_policy",
]
