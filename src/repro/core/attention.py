"""Policy-aware decode attention: the paper's algorithm, one step.

``decode_attend`` is the per-layer, per-step entry point.  It

  1. appends the new token's KV to the paged cache (allocating /
     evicting per the policy's priorities — RaaS Figure 5 semantics),
  2. scores pages against the query via representative keys
     (Quest-style min/max bound, paper §3.3),
  3. selects pages (Quest top-k; others attend the whole live cache —
     for RaaS the live cache *is* the O(L) retained set),
  4. runs the paged attention kernel (Pallas on TPU, jnp oracle on
     CPU) which also emits true per-page probability mass,
  5. refreshes priorities (RaaS timestamps / H2O accumulation).

Everything is one fused jittable function of the cache pytree.  All
policy semantics enter through the :class:`SparsityPolicy` object —
this module contains no per-policy branches.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core.policy_base import PolicyStats, SparsityPolicy, get_policy
from repro.kernels import ops


def decode_attend(cache: pc.PagedCache, q: jnp.ndarray, k_new: jnp.ndarray,
                  v_new: jnp.ndarray, cfg: RaasConfig,
                  policy: Optional[SparsityPolicy] = None,
                  has_prefill: bool = True,
                  impl: str = "jnp") -> Tuple[pc.PagedCache, jnp.ndarray,
                                              PolicyStats]:
    """One decode step of sparse attention for one layer.

    q      [B, H, hd]   (post-RoPE query for the new token)
    k_new  [B, KV, hd]  (post-RoPE key)
    v_new  [B, KV, hd]

    ``policy`` defaults to the registered policy for ``cfg.policy``;
    hot paths resolve it once and pass the object through.

    Returns (cache', ctx [B, H, hd], stats).
    """
    if policy is None:
        policy = get_policy(cfg.policy)
    B, H, hd = q.shape
    scale = 1.0 / (hd ** 0.5)

    # -- 1. append (evict if the policy's budget is exhausted) -------------
    cache, evicted = pc.append_token(
        cache, k_new, v_new,
        new_page_priority=policy.new_page_priority(cache, cfg),
        protect_recent=policy.protect_recent(cfg),
        pin_below_pos=policy.sink_pin(has_prefill, cfg),
    )

    # -- 2. representative page scores -------------------------------------
    valid = cache.valid_pages()
    if cfg.rep_scheme == "mean":
        rep_mid = 0.5 * (cache.rep_min + cache.rep_max)
        scores = ops.page_score(q, rep_mid, rep_mid, valid, scale, impl=impl)
    else:
        scores = ops.page_score(q, cache.rep_min, cache.rep_max, valid,
                                scale, impl=impl)

    # -- 3. page selection ---------------------------------------------------
    sel_idx = policy.select_pages(cache, scores, cfg)
    token_mask = cache.token_mask()
    if sel_idx is None:
        k_sel, v_sel, mask_sel = cache.k_pages, cache.v_pages, token_mask
    else:
        barange = jnp.arange(B)[:, None]
        k_sel = cache.k_pages[barange, sel_idx]
        v_sel = cache.v_pages[barange, sel_idx]
        mask_sel = token_mask[barange, sel_idx]

    # -- 4. paged attention + true per-page probability mass ---------------
    ctx, page_probs_sel = ops.paged_decode_attention(
        q, k_sel, v_sel, mask_sel, scale, impl=impl)

    # scatter per-page probs back to full slot space (H2O's signal)
    if sel_idx is None:
        page_probs = page_probs_sel
    else:
        page_probs = jnp.zeros(valid.shape, jnp.float32)
        page_probs = page_probs.at[jnp.arange(B)[:, None], sel_idx].add(
            page_probs_sel)

    # -- 5. priority refresh -------------------------------------------------
    cache = policy.refresh_priority(cache, scores, page_probs, cfg)

    stats = PolicyStats(
        evicted_slot=evicted,
        pages_attended=(mask_sel.any(-1)).sum(-1).astype(jnp.int32),
        tokens_cached=cache.tokens_cached(),
    )
    return cache, ctx, stats
