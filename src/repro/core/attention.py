"""Policy-aware decode attention: the paper's algorithm, one step.

``decode_attend`` is the per-layer, per-step entry point.  It

  1. appends the new token's KV to the paged cache (allocating /
     evicting per the policy's priorities — RaaS Figure 5 semantics),
  2. scores pages against the query via representative keys
     (Quest-style min/max bound, paper §3.3),
  3. asks the policy *which* pages to attend — the answer is an i32
     index table (Quest top-k; ``None`` = identity = the whole live
     cache, which for RaaS *is* the O(L) retained set),
  4. runs the paged attention kernel on the cache **in place**: the
     table is handed to the kernel (Pallas scalar prefetch / oracle
     gather), so no gathered KV copy is ever materialized here, and
     the kernel emits true per-page probability mass alongside the
     context,
  5. refreshes priorities (RaaS timestamps / H2O accumulation).

Everything is one fused jittable function of the cache pytree.  All
policy semantics enter through the :class:`SparsityPolicy` object —
this module contains no per-policy branches.  There is also no
scatter-back of page probabilities: non-selecting policies get them in
slot space straight from the kernel, and no built-in policy both
selects pages and consumes them (``SparsityPolicy.uses_page_probs``
gates the generic O(S)-scalar fallback for out-of-tree combinations).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RaasConfig
from repro.core import paged_cache as pc
from repro.core.policy_base import PolicyStats, SparsityPolicy, get_policy
from repro.kernels import ops


def decode_attend(cache: pc.PagedCache, q: jnp.ndarray, k_new: jnp.ndarray,
                  v_new: jnp.ndarray, cfg: RaasConfig,
                  policy: Optional[SparsityPolicy] = None,
                  has_prefill: bool = True,
                  write_mask: Optional[jnp.ndarray] = None,
                  impl: str = "jnp") -> Tuple[pc.PagedCache, jnp.ndarray,
                                              PolicyStats]:
    """One decode step of sparse attention for one layer.

    q      [B, H, hd]   (post-RoPE query for the new token)
    k_new  [B, KV, hd]  (post-RoPE key)
    v_new  [B, KV, hd]

    ``policy`` defaults to the registered policy for ``cfg.policy``;
    hot paths resolve it once and pass the object through.

    ``write_mask`` [B] bool (``None`` = all lanes): lanes where it is
    ``False`` are *frozen* — no KV append, no eviction, no priority
    refresh; their cache bits are bit-exactly unchanged by this step.
    The serving engine uses this to let finished lanes and lanes still
    mid-prefill ride along in a batched decode dispatch.

    Returns (cache', ctx [B, H, hd], stats).
    """
    if policy is None:
        policy = get_policy(cfg.policy)
    B, H, hd = q.shape
    scale = 1.0 / (hd ** 0.5)

    # -- 1. append (evict if the policy's budget is exhausted) -------------
    cache, evicted = pc.append_token(
        cache, k_new, v_new,
        new_page_priority=policy.new_page_priority(cache, cfg),
        protect_recent=policy.protect_recent(cfg),
        pin_below_pos=policy.sink_pin(has_prefill, cfg),
        write_mask=write_mask,
    )

    # -- 2. representative page scores -------------------------------------
    valid = cache.valid_pages()
    if cfg.rep_scheme == "mean":
        rep_mid = 0.5 * (cache.rep_min + cache.rep_max)
        scores = ops.page_score(q, rep_mid, rep_mid, valid, scale, impl=impl)
    else:
        scores = ops.page_score(q, cache.rep_min, cache.rep_max, valid,
                                scale, impl=impl)

    # -- 3./4. page selection as an index table + in-place attention -------
    sel_idx = policy.select_pages(cache, scores, cfg)
    ctx, page_probs_sel = ops.paged_decode_attention(
        q, cache.k_pages, cache.v_pages, cache.page_len, sel_idx, scale,
        impl=impl)

    if sel_idx is None:
        # identity table: the kernel's page probs are already slot space
        page_probs = page_probs_sel
        sel_len = cache.page_len
    else:
        sel_len = jnp.take_along_axis(cache.page_len, sel_idx, axis=1)
        if policy.uses_page_probs:
            # generic fallback for out-of-tree policies that both select
            # and consume probs; no built-in policy reaches this branch.
            page_probs = jnp.zeros(valid.shape, jnp.float32).at[
                jnp.arange(B)[:, None], sel_idx].add(page_probs_sel)
        else:
            page_probs = jnp.zeros(valid.shape, jnp.float32)

    # -- 5. priority refresh -------------------------------------------------
    refreshed = policy.refresh_priority(cache, scores, page_probs, cfg)
    if write_mask is not None:
        # frozen lanes keep their cache byte-for-byte: a lane
        # mid-prefill or already finished must be invariant under other
        # lanes' decode dispatches.  Blend every leaf, not just
        # `priority` — refresh_priority is an open extension point and
        # an out-of-tree policy may touch any field.
        refreshed = jax.tree.map(
            # untouched leaves come back as the same array object —
            # skip them so built-in policies pay O(S), not O(cache)
            lambda new, old: old if new is old else jnp.where(
                write_mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                new, old),
            refreshed, cache)
    cache = refreshed

    stats = PolicyStats(
        evicted_slot=evicted,
        pages_attended=(sel_len > 0).sum(-1).astype(jnp.int32),
        tokens_cached=cache.tokens_cached(),
    )
    return cache, ctx, stats
