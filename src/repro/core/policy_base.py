"""`SparsityPolicy`: the first-class KV-sparsity plugin interface.

Every cache-management strategy in the framework — RaaS (the paper),
Quest, H2O, StreamingLLM, Dense, and any out-of-tree variant — is a
subclass of :class:`SparsityPolicy` registered under a string id with
:func:`register_policy`.  The decode hot path
(:func:`repro.core.attention.decode_attend`) and the serving engine
dispatch exclusively through the policy object; there are no
``cfg.policy == ...`` string chains anywhere downstream of the
registry.

A policy is six hooks over the shared :class:`~repro.core.paged_cache.
PagedCache` substrate:

  ``cache_slots``       how many page slots the policy needs — this IS
                        the paper's O(L)-vs-O(N) memory axis, made
                        structural;
  ``select_pages``      which pages this step's attention touches, as
                        an i32 *index table* handed to the paged
                        kernel (Quest top-k; ``None`` = the identity
                        table = the whole live cache).  Selection is
                        indices-only: the kernel resolves the table
                        against the page-major cache in HBM, so a
                        policy never causes a gathered KV copy;
  ``refresh_priority``  how eviction priority evolves (RaaS timestamps,
                        H2O accumulation, Streaming: frozen);
  ``new_page_priority`` priority stamped on a freshly allocated page;
  ``protect_recent``    tokens in the recent window exempt from
                        eviction (H2O);
  ``sink_pin``          positions pinned as attention sinks
                        (StreamingLLM's prompt-less corner).

``finalize_config`` additionally lets a policy resolve deployment-time
static knobs (e.g. ``quest_raas`` deriving ``prefill_pages_hint`` from
the engine's prefill budget) without the engine knowing policy names.

Policies are *stateless singletons*: all per-sequence state lives in
the cache pytree, all knobs live in the hashable
:class:`~repro.config.RaasConfig`, so policy objects are safe to close
over in jitted functions.

Adding a policy means adding exactly one file::

    # src/repro/core/policies/my_policy.py
    from repro.core.policy_base import SparsityPolicy, register_policy

    @register_policy("my_policy")
    class MyPolicy(SparsityPolicy):
        def cache_slots(self, cfg, max_seq_len, prefill_len=0):
            ...

and importing it (the built-ins under ``repro.core.policies`` are
imported automatically; out-of-tree policies register at import time).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, NamedTuple, Optional, Tuple, Type

import jax.numpy as jnp

# Re-exported for policies: the shared masked-score sentinel.  Policies
# import constants from here, never from paged_cache directly (the
# `policy-imports` lint rule), so the cache layout stays encapsulated.
from repro.core.paged_cache import INF as INF  # noqa: F401

if TYPE_CHECKING:  # type-only; avoids an import cycle with repro.config
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache


class PolicyStats(NamedTuple):
    """Per-step observability (benchmarks/Fig-proxies consume this)."""

    evicted_slot: jnp.ndarray       # [B] i32, -1 = none
    pages_attended: jnp.ndarray     # [B] i32
    tokens_cached: jnp.ndarray      # [B] i32


class SparsityPolicy:
    """Base policy = Dense semantics: O(N) slots, attend everything,
    arrival-order priorities, no protection windows."""

    #: registry id; set by :func:`register_policy`.
    name: str = "base"

    #: whether ``refresh_priority`` consumes the true per-page
    #: attention probabilities.  The kernel always produces them for
    #: the pages it attends; this flag only controls whether the decode
    #: step scatters them back to slot space when the policy *also*
    #: selects a page subset (an O(S)-scalar fallback no built-in
    #: policy needs — H2O consumes probs but never selects).
    uses_page_probs: bool = False

    # -- capacity: the O(L) vs O(N) axis -----------------------------------
    def cache_slots(self, cfg: "RaasConfig", max_seq_len: int,
                    prefill_len: int = 0) -> int:
        """Number of page slots required to serve ``max_seq_len``.

        Default: O(N).  +1 because prefill never shares a page with
        decode, so a partial prefill tail page costs one extra slot.
        """
        return -(-max_seq_len // cfg.page_size) + 1

    def budget_slots(self, cfg: "RaasConfig", prefill_len: int) -> int:
        """Shared O(L) helper: the paper's budget includes pinned
        prefill; guarantee at least one decode page so generation can
        proceed."""
        pre_pages = -(-prefill_len // cfg.page_size)
        return max(cfg.budget_pages, pre_pages + 1)

    # -- selection: which pages this step's attention touches --------------
    def select_pages(self, cache: "PagedCache", scores: jnp.ndarray,
                     cfg: "RaasConfig") -> Optional[jnp.ndarray]:
        """Index table [B, K] of page slots for top-k-style policies,
        or ``None`` for the identity table (attend the whole live
        cache — for O(L) policies the live cache *is* the retained
        set).  Entries must be duplicate-free valid slot indices;
        empty pages (``page_len == 0``) are masked by the kernel, so
        over-selection is harmless."""
        return None

    # -- eviction-priority dynamics ----------------------------------------
    def refresh_priority(self, cache: "PagedCache", scores: jnp.ndarray,
                         page_probs: jnp.ndarray,
                         cfg: "RaasConfig") -> "PagedCache":
        """Update per-page priorities after a decode step.

        ``scores``: estimated page scores [B, S] (rep-key based, logit
        scale).  ``page_probs``: true per-page attention probability
        mass [B, S] (from the attention kernel; H2O's signal).
        Default: static priorities (arrival order)."""
        return cache

    def new_page_priority(self, cache: "PagedCache",
                          cfg: "RaasConfig") -> jnp.ndarray:
        """[B] f32 priority for a freshly allocated page.  Default:
        current length = arrival order / RaaS timestamp."""
        return cache.cur_len.astype(jnp.float32)

    # -- protection windows -------------------------------------------------
    def protect_recent(self, cfg: "RaasConfig") -> int:
        """Tokens inside this trailing window are exempt from eviction."""
        return 0

    def sink_pin(self, has_prefill: bool, cfg: "RaasConfig") -> int:
        """Pages whose first token position is below this threshold are
        pinned (StreamingLLM sinks for prompt-less decode)."""
        return 0

    # -- deployment-time config resolution ----------------------------------
    def finalize_config(self, cfg: "RaasConfig",
                        prefill_len: int) -> "RaasConfig":
        """Resolve static knobs that depend on the serving deployment
        (e.g. prefill page counts).  Returns a (possibly new) config."""
        return cfg

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, SparsityPolicy] = {}


def register_policy(name: str):
    """Class decorator: instantiate and register a policy under ``name``."""

    def deco(cls: Type[SparsityPolicy]) -> Type[SparsityPolicy]:
        existing = _REGISTRY.get(name)
        if existing is not None:
            old = type(existing)
            # tolerate re-registration only from a module reload of the
            # same class; distinct classes may not share an id.
            if (old.__module__, old.__qualname__) != (cls.__module__,
                                                      cls.__qualname__):
                raise ValueError(
                    f"policy id {name!r} already registered by "
                    f"{old.__module__}.{old.__qualname__}")
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def _ensure_builtin_policies() -> None:
    # Importing the package registers the built-in policy modules.
    import repro.core.policies  # noqa: F401


def get_policy(name: str) -> SparsityPolicy:
    """Resolve a policy id to its registered singleton."""
    _ensure_builtin_policies()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sparsity policy {name!r}; available: "
            f"{available_policies()}") from None


def available_policies() -> Tuple[str, ...]:
    _ensure_builtin_policies()
    return tuple(sorted(_REGISTRY))
