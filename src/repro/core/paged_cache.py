"""Slot-based fixed-capacity paged KV cache (the O(L)-memory substrate).

The reference RaaS implementation (HF + Quest CUDA) allocates/frees KV
pages dynamically on the host.  On TPU under jit everything must be
static-shape, so "eviction" here means *overwriting a victim slot*:

    k_pages / v_pages  [B, KV, S, P, hd]   S = n_slots, P = page_size
    rep_min / rep_max  [B, KV, S, hd]      Quest representative keys
    priority           [B, S] f32          policy-specific eviction key
    page_pos           [B, S] i32          first-token position, -1 = free
    page_len           [B, S] i32          tokens filled (0..P)
    pinned             [B, S] bool         prefill pages are exempt
    refcount           [B, S] i32          page-pool references (see below)
    active_slot        [B]    i32          slot currently being filled (-1)
    cur_len            [B]    i32          tokens written so far

DESIGN — kernel-native page-major layout
========================================
``k_pages``/``v_pages`` are stored **page-major per kv-head**:
``[B, KV, S, P, hd]``.  This is the exact layout the Pallas decode
kernel (:mod:`repro.kernels.paged_attention`) indexes with its
``(batch, kv_head, page)`` grid, so the kernel's ``index_map`` can
resolve any page slot straight out of HBM — no reshape, no transpose,
no gathered copy is ever made of the cache.  The representative keys
mirror it (``[B, KV, S, hd]``) for the same reason: the page-score
kernel blocks over the slot axis with the kv-head axis already
outermost.  Live tokens always occupy a *prefix* of each page
(``page_len`` of them); that prefix contract is what lets the kernels
mask with a single per-page length instead of a per-token mask.

All slot-metadata operations are O(S) vector ops per decode step —
fully jittable, batched, and shardable on the batch axis.  The policy
layer (policies/) decides priorities; this module only knows "evict
argmin priority among unpinned".

DESIGN — refcounted page aliasing (prefix caching)
==================================================
``refcount`` [B, S] i32 counts the independent claims on a slot's
*contents*: the request currently running on the lane holds one claim
on every slot it writes or mounts, and the host-side prefix index
(:mod:`repro.core.page_pool`) holds one claim on every slot it has
registered as a shareable prompt prefix (including *parked* prefixes —
pages whose lane has been freed but whose prefill KV is retained for
future aliasing).  The pool invariant every write path here upholds:

  * a slot with ``refcount > 1`` is never evicted (:func:`_eviction_key`
    hard-protects it like a pinned page), never overwritten
    (:func:`ingest_prefill_chunk` masks such writes out), and never
    reset (only the pool's transition ops may decref it);
  * a *divergent* append into a shared partial page copies-on-write:
    :func:`append_token` allocates a private slot, copies the shared
    page's bytes and metadata, decrefs the shared slot, and appends
    into the private copy — byte-identical to an unshared lane.

``refcount`` mutation is confined to this module and
:mod:`repro.core.page_pool` (the ``pool-refcount-outside-pool`` lint
rule enforces it): everything above the pool reasons about lanes and
prefixes, never raw counts.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

INF = jnp.float32(1e30)


class CacheSpec(NamedTuple):
    """Static cache geometry (hashable; safe as a jit static arg)."""

    n_slots: int
    page_size: int
    n_kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.float32

    @property
    def capacity_tokens(self) -> int:
        return self.n_slots * self.page_size


class PagedCache(NamedTuple):
    k_pages: jnp.ndarray    # [B, KV, S, P, hd]
    v_pages: jnp.ndarray    # [B, KV, S, P, hd]
    rep_min: jnp.ndarray    # [B, KV, S, hd] f32
    rep_max: jnp.ndarray    # [B, KV, S, hd] f32
    priority: jnp.ndarray   # [B, S] f32
    page_pos: jnp.ndarray   # [B, S] i32 (-1 = free)
    page_len: jnp.ndarray   # [B, S] i32
    pinned: jnp.ndarray     # [B, S] bool
    refcount: jnp.ndarray   # [B, S] i32 (0 = unreferenced)
    active_slot: jnp.ndarray  # [B] i32 (-1 = none)
    cur_len: jnp.ndarray    # [B] i32

    @property
    def batch(self) -> int:
        return self.k_pages.shape[0]

    @property
    def n_slots(self) -> int:
        return self.k_pages.shape[2]

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[3]

    def valid_pages(self) -> jnp.ndarray:
        """[B, S] bool — slots holding at least one token."""
        return self.page_len > 0

    def token_mask(self) -> jnp.ndarray:
        """[B, S, P] bool — live token positions (prefix per page)."""
        P = self.page_size
        return jnp.arange(P)[None, None, :] < self.page_len[:, :, None]

    def tokens_cached(self) -> jnp.ndarray:
        """[B] i32 — number of live tokens (<= capacity)."""
        return self.page_len.sum(axis=1)


def cache_nbytes(cache: PagedCache, per_device: bool = False) -> int:
    """Byte footprint of every array the cache allocates per lane
    batch — K/V pages, representative keys, and all per-page /
    per-lane metadata.

    ``per_device=True`` counts ONE device's addressable shard instead,
    from each leaf's ``Sharding.shard_shape`` — the same answer for a
    single-device cache (shard == global) and ``global / n_data`` for
    a lane-sharded cache under a mesh, so callers can assert the
    sharded engine's O(L * B / n_dev) per-device memory without
    transferring a byte.
    """
    total = 0
    for x in jax.tree.leaves(cache):
        shape = x.sharding.shard_shape(x.shape) if per_device else x.shape
        n = 1
        for d in shape:
            n *= d
        total += n * x.dtype.itemsize
    return total


def init_cache(spec: CacheSpec, batch: int) -> PagedCache:
    S, P, KV, hd = spec.n_slots, spec.page_size, spec.n_kv_heads, spec.head_dim
    z = lambda *shape: jnp.zeros(shape, spec.dtype)
    return PagedCache(
        k_pages=z(batch, KV, S, P, hd),
        v_pages=z(batch, KV, S, P, hd),
        rep_min=jnp.full((batch, KV, S, hd), INF, jnp.float32),
        rep_max=jnp.full((batch, KV, S, hd), -INF, jnp.float32),
        priority=jnp.zeros((batch, S), jnp.float32),
        page_pos=jnp.full((batch, S), -1, jnp.int32),
        page_len=jnp.zeros((batch, S), jnp.int32),
        pinned=jnp.zeros((batch, S), jnp.bool_),
        refcount=jnp.zeros((batch, S), jnp.int32),
        active_slot=jnp.full((batch,), -1, jnp.int32),
        cur_len=jnp.zeros((batch,), jnp.int32),
    )


def ingest_prefill(cache: PagedCache, k: jnp.ndarray, v: jnp.ndarray,
                   lengths: jnp.ndarray, pin: bool = True) -> PagedCache:
    """Pack prefill keys/values into the first ceil(len/P) slots.

    k, v: [B, S_pre, KV, hd] (post-RoPE, token-major as produced by the
    projection).  The one-shot transpose into the page-major cache
    layout happens here — at prefill time, once per sequence — so the
    per-step decode path never rearranges KV bytes.  ``lengths``: [B]
    i32 actual prefill length per sequence (ragged batches supported;
    positions >= length are ignored).  Prefill pages are pinned (paper
    §3.2: all prefill tokens are retained; phoenix tokens live there).

    Decode tokens never share a page with prefill: ``active_slot`` is
    left at -1 so the first appended token allocates a fresh page.
    """
    B, S_pre, KV, hd = k.shape
    S, P = cache.n_slots, cache.page_size
    n_pre_pages = -(-S_pre // P)
    if n_pre_pages > S:
        raise ValueError(
            f"prefill ({S_pre} tokens = {n_pre_pages} pages) exceeds cache "
            f"capacity ({S} slots); the paper recommends Quest for "
            f"long-prefill workloads")
    pad = n_pre_pages * P - S_pre
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = kp.reshape(B, n_pre_pages, P, KV, hd)       # token-major pages
    vp = vp.reshape(B, n_pre_pages, P, KV, hd)

    pos_in_seq = (jnp.arange(n_pre_pages * P)
                  .reshape(n_pre_pages, P))                       # [pages, P]
    live = pos_in_seq[None] < lengths[:, None, None]              # [B, pages, P]
    plen = live.sum(-1).astype(jnp.int32)                         # [B, pages]
    ppos = (pos_in_seq[:, 0][None] * jnp.ones((B, 1), jnp.int32))
    ppos = jnp.where(plen > 0, ppos, -1)

    kf = jnp.where(live[..., None, None], kp.astype(jnp.float32), INF)
    rep_min = kf.min(axis=2).transpose(0, 2, 1, 3)        # [B,KV,pages,hd]
    kf = jnp.where(live[..., None, None], kp.astype(jnp.float32), -INF)
    rep_max = kf.max(axis=2).transpose(0, 2, 1, 3)

    # page-major, kv-head-outermost: [B, KV, pages, P, hd]
    kp = jnp.where(live[..., None, None], kp, 0).transpose(0, 3, 1, 2, 4)
    vp = jnp.where(live[..., None, None], vp, 0).transpose(0, 3, 1, 2, 4)
    k_pages = cache.k_pages.at[:, :, :n_pre_pages].set(
        kp.astype(cache.k_pages.dtype))
    v_pages = cache.v_pages.at[:, :, :n_pre_pages].set(
        vp.astype(cache.v_pages.dtype))
    return cache._replace(
        k_pages=k_pages,
        v_pages=v_pages,
        rep_min=cache.rep_min.at[:, :, :n_pre_pages].set(rep_min),
        rep_max=cache.rep_max.at[:, :, :n_pre_pages].set(rep_max),
        priority=cache.priority.at[:, :n_pre_pages].set(
            jnp.where(plen > 0, ppos.astype(jnp.float32), 0.0)),
        page_pos=cache.page_pos.at[:, :n_pre_pages].set(ppos),
        page_len=cache.page_len.at[:, :n_pre_pages].set(plen),
        pinned=cache.pinned.at[:, :n_pre_pages].set(
            jnp.logical_and(pin, plen > 0)),
        refcount=cache.refcount.at[:, :n_pre_pages].set(
            (plen > 0).astype(jnp.int32)),
        active_slot=jnp.full((B,), -1, jnp.int32),
        cur_len=lengths.astype(jnp.int32),
    )


def reset_lanes(cache: PagedCache, mask: jnp.ndarray) -> PagedCache:
    """Return ``cache`` with the lanes selected by ``mask`` [B] bool
    restored to the fresh (empty) state, entirely on device.

    This is how the engine recycles a lane at admission: metadata is
    cleared (``page_len == 0`` makes every stale K/V byte dead — the
    prefix contract masks it in every kernel), so no K/V page needs to
    be zeroed, copied or re-materialized on host.

    A reset wipes ``refcount`` with the rest of the lane: callers must
    only reset lanes the prefix index holds no claim on.  Lanes with
    registered/parked pages go through
    :func:`repro.core.page_pool.transition_lanes` (RELEASE keeps the
    index's claim; RESET there asserts none exists).
    """
    m1 = mask[:, None]
    m3 = mask[:, None, None, None]
    return cache._replace(
        priority=jnp.where(m1, 0.0, cache.priority),
        page_pos=jnp.where(m1, -1, cache.page_pos),
        page_len=jnp.where(m1, 0, cache.page_len),
        pinned=jnp.where(m1, False, cache.pinned),
        refcount=jnp.where(m1, 0, cache.refcount),
        rep_min=jnp.where(m3, INF, cache.rep_min),
        rep_max=jnp.where(m3, -INF, cache.rep_max),
        active_slot=jnp.where(mask, -1, cache.active_slot),
        cur_len=jnp.where(mask, 0, cache.cur_len),
    )


def scrub_lanes(cache: PagedCache, mask: jnp.ndarray) -> PagedCache:
    """Return ``cache`` with the masked lanes' K/V page *payload*
    zeroed and their representative keys re-initialized.

    :func:`reset_lanes` is deliberately metadata-only: stale bytes are
    dead under the prefix contract.  That contract assumes the stale
    bytes are *finite* — masked arithmetic (``0 * NaN == NaN``) lets
    non-finite garbage poison reductions that merely range over a dead
    slot.  A lane quarantined for non-finite logits may hold exactly
    such bytes, so the engine scrubs its payload before the lane can
    be recycled.  Handles period-stacked leaves like every lane op
    (the lane axis is located per field via :data:`AFTER_LANE`).
    """
    def m(name: str) -> jnp.ndarray:
        return mask.reshape((-1,) + (1,) * AFTER_LANE[name])
    return cache._replace(
        k_pages=jnp.where(m("k_pages"), 0, cache.k_pages),
        v_pages=jnp.where(m("v_pages"), 0, cache.v_pages),
        rep_min=jnp.where(m("rep_min"), INF, cache.rep_min),
        rep_max=jnp.where(m("rep_max"), -INF, cache.rep_max),
    )


# Per-field rank *after* the lane axis: cache leaves may carry leading
# stacked axes (the engine stacks layers as [n_periods, B, ...]), so
# the lane axis of field ``f`` is ``x.ndim - 1 - AFTER_LANE[f]``.
# Single source for every whole-lane slice (clone / snapshot / restore).
AFTER_LANE = dict(k_pages=4, v_pages=4, rep_min=3, rep_max=3,
                  priority=1, page_pos=1, page_len=1, pinned=1,
                  refcount=1, active_slot=0, cur_len=0)


def lane_axis(x: jnp.ndarray, name: str) -> int:
    """Index of the lane axis in cache leaf ``name`` (stacking-proof)."""
    return x.ndim - 1 - AFTER_LANE[name]


def snapshot_lane(cache: PagedCache, lane: jnp.ndarray) -> PagedCache:
    """One lane's complete cache state, lane axis removed from every
    leaf — the device half of lane checkpointing.

    The returned ``PagedCache`` container holds per-lane *rows* (one
    rank lower than the batched cache), ready for a single
    device->host transfer.  Pages, representative keys and all slot
    metadata ride along, so a later :func:`page_pool.restore_lane`
    onto any free lane reproduces the lane byte-identically — the lane
    axis is elementwise everywhere, so lane identity carries no state.
    """
    def take(name: str) -> jnp.ndarray:
        x = getattr(cache, name)
        return jax.lax.dynamic_index_in_dim(x, lane,
                                            axis=lane_axis(x, name),
                                            keepdims=False)
    return PagedCache(**{f: take(f) for f in PagedCache._fields})


def ingest_prefill_chunk(cache: PagedCache, k: jnp.ndarray, v: jnp.ndarray,
                         chunk_lens: jnp.ndarray,
                         pin: bool = True) -> PagedCache:
    """Append one *chunk* of prefill KV per lane at ``cache.cur_len``.

    k, v: [B, C, KV, hd] (post-RoPE, token-major); ``chunk_lens`` [B]
    i32 live tokens of this chunk per lane (0 = the lane is a no-op:
    nothing in it is touched — lanes mid-decode or empty ride along in
    a batched chunked-prefill dispatch unharmed).

    The engine keeps chunks page-aligned: every lane with
    ``chunk_lens > 0`` has ``cur_len % page_size == 0`` (it dispatches
    chunks of ``prefill_chunk`` tokens, a page multiple, so only the
    *final* chunk of a prompt is ragged — after which the lane leaves
    prefill).  Pages are written at slots ``cur_len // P ..``, which
    keeps the whole prefill of a lane contiguous from slot 0 exactly as
    :func:`ingest_prefill` lays it out, so a multi-chunk ingest is
    indistinguishable from a one-shot ingest of the same tokens.

    Capacity is the caller's contract (checked host-side at admission):
    out-of-range pages and shared pages (``refcount > 1`` — pool
    property) are dropped from the scatter entirely, and ``cur_len``
    advances only by the tokens actually written, so
    ``cur_len == tokens_cached()`` holds even after a contract
    violation — corruption surfaces as a loudly stalled lane, never as
    silently divergent accounting.
    """
    B, C, KV, hd = k.shape
    S, P = cache.n_slots, cache.page_size
    nC = -(-C // P)
    pad = nC * P - C
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) \
        .reshape(B, nC, P, KV, hd)
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) \
        .reshape(B, nC, P, KV, hd)

    start = cache.cur_len                                     # [B]
    pos_in_chunk = jnp.arange(nC * P).reshape(nC, P)
    live = pos_in_chunk[None] < chunk_lens[:, None, None]     # [B, nC, P]
    plen = live.sum(-1).astype(jnp.int32)                     # [B, nC]
    raw_slots = start[:, None] // P + jnp.arange(nC)[None]    # [B, nC]
    bidx = jnp.arange(B)[:, None]
    rc = cache.refcount[bidx, jnp.clip(raw_slots, 0, S - 1)]
    # pages beyond capacity must not overwrite the last slot, and
    # shared pages (refcount > 1) belong to the pool — never clobbered
    write = (plen > 0) & (raw_slots < S) & (rc <= 1)          # [B, nC]
    # blocked pages scatter to slot S: ``mode='drop'`` discards them
    # outright, so they neither blend nor duplicate a real slot index
    # (duplicates would let a dropped page clobber the real write)
    slots = jnp.where(write, raw_slots, S)
    ppos = start[:, None] + pos_in_chunk[:, 0][None]          # [B, nC]
    # per-page representative keys over live chunk tokens
    kf = jnp.where(live[..., None, None], kp.astype(jnp.float32), INF)
    rmin_new = kf.min(axis=2)                                 # [B, nC, KV, hd]
    kf = jnp.where(live[..., None, None], kp.astype(jnp.float32), -INF)
    rmax_new = kf.max(axis=2)

    # [B, nC, KV, P, hd] to match the advanced-indexing scatter order
    kw = jnp.where(live[..., None, None], kp, 0).transpose(0, 1, 3, 2, 4)
    vw = jnp.where(live[..., None, None], vp, 0).transpose(0, 1, 3, 2, 4)
    k_pages = cache.k_pages.at[bidx, :, slots].set(
        kw.astype(cache.k_pages.dtype), mode="drop")
    v_pages = cache.v_pages.at[bidx, :, slots].set(
        vw.astype(cache.v_pages.dtype), mode="drop")
    rep_min = cache.rep_min.at[bidx, :, slots].set(rmin_new, mode="drop")
    rep_max = cache.rep_max.at[bidx, :, slots].set(rmax_new, mode="drop")
    return cache._replace(
        k_pages=k_pages, v_pages=v_pages,
        rep_min=rep_min, rep_max=rep_max,
        priority=cache.priority.at[bidx, slots].set(
            ppos.astype(jnp.float32), mode="drop"),
        page_pos=cache.page_pos.at[bidx, slots].set(ppos, mode="drop"),
        page_len=cache.page_len.at[bidx, slots].set(plen, mode="drop"),
        pinned=cache.pinned.at[bidx, slots].set(
            jnp.broadcast_to(jnp.bool_(pin), slots.shape), mode="drop"),
        refcount=cache.refcount.at[bidx, slots].set(
            jnp.ones(slots.shape, jnp.int32), mode="drop"),
        cur_len=cache.cur_len + (plen * write).sum(-1).astype(jnp.int32),
    )


def _eviction_key(cache: PagedCache, protect_recent: int) -> jnp.ndarray:
    """[B, S] f32 — argmin of this picks the victim slot.

    Free slots are preferred (-INF); pinned pages and shared pages
    (``refcount > 1`` — the pool or another claimant still needs the
    bytes) are hard-protected (+INF).  The active page and pages
    inside the recent-token window
    are *softly* protected: when every unpinned page is soft-protected
    (pathologically tight budgets), the soft protections are dropped in
    order (recent first, then active) rather than evicting a pinned
    prefill page — the paper's invariant is that prefill KV survives.
    """
    free = cache.page_pos < 0
    S = cache.priority.shape[1]
    is_active = (jnp.arange(S)[None] == cache.active_slot[:, None])
    recent_edge = cache.cur_len[:, None] - protect_recent
    in_recent = ((cache.page_pos + cache.page_len) > recent_edge) & ~free

    base = jnp.where(cache.pinned | (cache.refcount > 1),
                     INF, cache.priority)
    base = jnp.where(free, -INF, base)
    k_recent = jnp.where(in_recent, INF, base)
    k_full = jnp.where(is_active, INF, k_recent)

    def has_victim(k):
        return (jnp.min(k, axis=1, keepdims=True) < INF / 2)

    key = jnp.where(has_victim(k_full), k_full,
                    jnp.where(has_victim(k_recent), k_recent, base))
    return key


def append_token(cache: PagedCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 new_page_priority: jnp.ndarray,
                 protect_recent: int = 0,
                 pin_below_pos: int = 0,
                 write_mask: Optional[jnp.ndarray] = None
                 ) -> Tuple[PagedCache, jnp.ndarray]:
    """Append one token's KV per sequence, evicting if necessary.

    k_new, v_new: [B, KV, hd] (post-RoPE).  ``new_page_priority``: [B]
    f32 priority assigned to a freshly allocated page.  ``pin_below_pos``
    pins pages whose first token position is below the threshold
    (StreamingLLM sink behaviour for prompt-less decode).

    ``write_mask`` [B] bool (``None`` = all lanes): lanes where it is
    ``False`` are left bit-exactly unchanged — no allocation, no
    eviction, no KV write, no ``cur_len`` advance.  This is how the
    serving engine freezes finished lanes and lanes still mid-prefill
    while the fused decode chunk advances the others.

    The KV write is a single-slot in-place update of the page-major
    cache (O(P) bytes per kv head) — never a copy of other pages,
    except on copy-on-write: a lane whose *active* page is shared
    (``refcount > 1`` — a parked session or the prefix index still
    claims its bytes) allocates a private slot first, copies that one
    page's KV + metadata into it, decrefs the shared slot, and appends
    into the copy.  The shared page is left bit-exact, and the lane's
    own view is byte-identical to an unshared lane's.

    Returns (cache, evicted_slot [B] i32; -1 where no eviction happened
    — i.e. a free slot was used, the active page had room, or the lane
    was masked off).
    """
    B, KV, hd = k_new.shape
    S, P = cache.n_slots, cache.page_size
    barange = jnp.arange(B)
    wm = jnp.ones((B,), bool) if write_mask is None else write_mask

    active = cache.active_slot
    have_active = active >= 0
    active_idx = jnp.where(have_active, active, 0)
    active_len = cache.page_len[barange, active_idx]
    active_full = jnp.where(have_active, active_len >= P, True)
    active_shared = have_active & \
        (cache.refcount[barange, active_idx] > 1)

    # copy-on-write: room left in the active page, but its bytes are
    # shared — divert the append into a freshly allocated private copy
    cow = ~active_full & active_shared & wm
    need_alloc = (active_full | active_shared) & wm
    evict_key = _eviction_key(cache, protect_recent)
    victim = jnp.argmin(evict_key, axis=1).astype(jnp.int32)
    victim_was_free = cache.page_pos[barange, victim] < 0
    evicted = jnp.where(need_alloc & ~victim_was_free, victim, -1)

    slot = jnp.where(need_alloc, victim, active_idx)
    fresh = need_alloc & ~cow
    # reset the victim slot where allocating (or clone the shared
    # active page into it where copying-on-write), then write the token
    page_pos = cache.page_pos.at[barange, slot].set(
        jnp.where(fresh, cache.cur_len,
                  jnp.where(cow, cache.page_pos[barange, active_idx],
                            cache.page_pos[barange, slot])))
    page_len = cache.page_len.at[barange, slot].set(
        jnp.where(fresh, 0,
                  jnp.where(cow, active_len,
                            cache.page_len[barange, slot])))
    # NB mixed advanced/basic indexing [barange, :, slot] broadcasts the
    # advanced axes to the front: the result is [B, KV, ...].
    c2 = cow[:, None, None]
    rep_min = cache.rep_min.at[barange, :, slot].set(
        jnp.where(fresh[:, None, None], INF,
                  jnp.where(c2, cache.rep_min[barange, :, active_idx],
                            cache.rep_min[barange, :, slot])))
    rep_max = cache.rep_max.at[barange, :, slot].set(
        jnp.where(fresh[:, None, None], -INF,
                  jnp.where(c2, cache.rep_max[barange, :, active_idx],
                            cache.rep_max[barange, :, slot])))
    priority = cache.priority.at[barange, slot].set(
        jnp.where(fresh, new_page_priority,
                  jnp.where(cow, cache.priority[barange, active_idx],
                            cache.priority[barange, slot])))
    pinned = cache.pinned.at[barange, slot].set(
        jnp.where(fresh,
                  cache.cur_len < pin_below_pos,
                  jnp.where(cow, cache.pinned[barange, active_idx],
                            cache.pinned[barange, slot])))
    # the allocated slot is privately owned; a COW source loses this
    # lane's claim (the other claimants keep theirs)
    refcount = cache.refcount.at[barange, slot].set(
        jnp.where(need_alloc, 1, cache.refcount[barange, slot]))
    refcount = refcount.at[barange, active_idx].add(
        -(cow.astype(jnp.int32)))
    # zero the KV of a reset page so stale tokens can't leak through;
    # a COW page instead receives the shared page's exact bytes
    c4 = cow[:, None, None, None]
    f4 = fresh[:, None, None, None]
    k_pages = cache.k_pages.at[barange, :, slot].set(
        jnp.where(f4, 0,
                  jnp.where(c4, cache.k_pages[barange, :, active_idx],  # analysis: allow=paged-gather-outside-kernels -- COW clone reads one shared page per lane, owner module
                            cache.k_pages[barange, :, slot])))  # analysis: allow=paged-gather-outside-kernels -- page-reset RMW reads one page per lane, owner module
    v_pages = cache.v_pages.at[barange, :, slot].set(
        jnp.where(f4, 0,
                  jnp.where(c4, cache.v_pages[barange, :, active_idx],  # analysis: allow=paged-gather-outside-kernels -- COW clone reads one shared page per lane, owner module
                            cache.v_pages[barange, :, slot])))  # analysis: allow=paged-gather-outside-kernels -- page-reset RMW reads one page per lane, owner module

    # masked lanes write their existing byte back at a safe offset —
    # a bit-exact no-op — so the scatter shape stays static.
    offset = jnp.where(wm, jnp.where(fresh, 0, active_len), 0)
    w3 = wm[:, None, None]                     # [B,1,1] vs [B,KV,hd]
    k_pages = k_pages.at[barange, :, slot, offset].set(
        jnp.where(w3, k_new.astype(k_pages.dtype),
                  k_pages[barange, :, slot, offset]))  # analysis: allow=paged-gather-outside-kernels -- single-token append RMW reads one [KV,hd] row per lane, owner module
    v_pages = v_pages.at[barange, :, slot, offset].set(
        jnp.where(w3, v_new.astype(v_pages.dtype),
                  v_pages[barange, :, slot, offset]))  # analysis: allow=paged-gather-outside-kernels -- single-token append RMW reads one [KV,hd] row per lane, owner module
    # +/-INF are the identity elements of the running min/max
    rep_min = rep_min.at[barange, :, slot].min(
        jnp.where(w3, k_new.astype(jnp.float32), INF))
    rep_max = rep_max.at[barange, :, slot].max(
        jnp.where(w3, k_new.astype(jnp.float32), -INF))
    page_len = page_len.at[barange, slot].add(wm.astype(jnp.int32))

    new_cache = cache._replace(
        k_pages=k_pages, v_pages=v_pages,
        rep_min=rep_min, rep_max=rep_max,
        priority=priority, page_pos=page_pos, page_len=page_len,
        pinned=pinned, refcount=refcount,
        active_slot=jnp.where(wm, slot, cache.active_slot),
        cur_len=cache.cur_len + wm.astype(jnp.int32),
    )
    return new_cache, evicted
