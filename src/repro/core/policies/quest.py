"""Quest: O(N) retention, top-k page *selection* at attention time.

Never evicts; each step attends the ``quest_topk_pages`` highest-
scoring pages (by the min/max representative-key bound) plus the
active page.  O(L) attention time, O(N) memory.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core.policy_base import INF, SparsityPolicy, register_policy

if TYPE_CHECKING:
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache


@register_policy("quest")
class QuestPolicy(SparsityPolicy):
    """O(N) memory (base-class slots), top-k page selection."""

    def select_pages(self, cache: "PagedCache", scores: jnp.ndarray,
                     cfg: "RaasConfig") -> Optional[jnp.ndarray]:
        B, S = scores.shape
        k = min(cfg.quest_topk_pages, S)
        # always include the active page (recent tokens), Quest-style.
        active = jnp.where(cache.active_slot >= 0, cache.active_slot, 0)
        boosted = scores.at[jnp.arange(B), active].set(INF)
        _, idx = jax.lax.top_k(boosted, k)
        return idx.astype(jnp.int32)
