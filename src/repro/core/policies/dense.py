"""Dense: O(N) slots, attends everything.  The no-sparsity baseline.

Everything is inherited from :class:`SparsityPolicy`, whose defaults
*are* dense semantics — this file exists so ``dense`` is a registered
id like any other policy.
"""
from __future__ import annotations

from repro.core.policy_base import SparsityPolicy, register_policy


@register_policy("dense")
class DensePolicy(SparsityPolicy):
    pass
