"""Quest×RaaS hybrid (the paper's §Limitations recommendation).

Prefill pages are all *retained* and Quest-selected at attention time;
decode pages get the RaaS timestamp budget -> O(N_prefill + L) memory,
O(k + L) attention time.  Recommended for long-prefill workloads the
pure-RaaS pinned-prefill budget cannot absorb.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core.policies.raas import RaasPolicy
from repro.core.policy_base import register_policy

if TYPE_CHECKING:
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache


@register_policy("quest_raas")
class QuestRaasPolicy(RaasPolicy):
    """RaaS refresh dynamics + Quest selection over the prefill range."""

    def cache_slots(self, cfg: "RaasConfig", max_seq_len: int,
                    prefill_len: int = 0) -> int:
        pre_pages = -(-prefill_len // cfg.page_size)
        return pre_pages + cfg.budget_pages

    def select_pages(self, cache: "PagedCache", scores: jnp.ndarray,
                     cfg: "RaasConfig") -> Optional[jnp.ndarray]:
        # top-k among the (static) prefill slot range + every decode
        # slot.  Slot layout guarantees prefill occupies [0, n_pre).
        B, S = scores.shape
        n_pre = cfg.prefill_pages_hint
        if n_pre == 0 or n_pre >= S:
            return None
        k = min(cfg.quest_topk_pages, n_pre)
        _, idx = jax.lax.top_k(scores[:, :n_pre], k)
        decode_idx = jnp.broadcast_to(jnp.arange(n_pre, S), (B, S - n_pre))
        return jnp.concatenate([idx, decode_idx], axis=1).astype(jnp.int32)

    def finalize_config(self, cfg: "RaasConfig",
                        prefill_len: int) -> "RaasConfig":
        # the static prefill page count must be known at trace time;
        # derive it from the deployment's prefill budget if unset.
        if cfg.prefill_pages_hint == 0:
            return dataclasses.replace(
                cfg, prefill_pages_hint=-(-prefill_len // cfg.page_size))
        return cfg
