"""RaaS (the paper, §3.2): timestamp-refresh eviction over an O(L) cache.

priority = timestamp of the last step whose *estimated* page score
passed the alpha/top-r rule; evict argmin; prefill pinned.  Milestone
pages stay resident exactly while they still receive attention mass;
phoenix tokens live in the pinned prefill.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax.numpy as jnp

from repro.core.policy_base import SparsityPolicy, register_policy

if TYPE_CHECKING:
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache

_NEG_INF = -1e30


def raas_selected_mask(scores: jnp.ndarray, valid: jnp.ndarray,
                       cfg: "RaasConfig") -> jnp.ndarray:
    """[B, S] bool — pages whose timestamp refreshes this step.

    ``scores`` are logit-scale estimated page scores (-inf at invalid).
    ``use_top_r``: refresh the ceil(r * n_valid) highest-scoring pages
    (the paper's recommended r = 50% rule).  Otherwise: refresh pages
    whose softmax probability exceeds alpha (paper: "two sides of the
    same coin").
    """
    if cfg.use_top_r:
        # rank pages descending by score; rank < ceil(r * n_valid)
        order = jnp.argsort(-scores, axis=1)
        ranks = jnp.argsort(order, axis=1)               # rank of each slot
        n_valid = valid.sum(axis=1, keepdims=True)
        cutoff = jnp.ceil(cfg.top_r * n_valid).astype(jnp.int32)
        return (ranks < cutoff) & valid
    # alpha rule on estimated softmax probabilities
    m = jnp.max(jnp.where(valid, scores, _NEG_INF), axis=1, keepdims=True)
    e = jnp.where(valid, jnp.exp(scores - m), 0.0)
    probs = e / jnp.maximum(e.sum(axis=1, keepdims=True), 1e-30)
    return (probs > cfg.alpha) & valid


@register_policy("raas")
class RaasPolicy(SparsityPolicy):
    """O(L) memory, O(L) time: the paper's contribution."""

    def cache_slots(self, cfg: "RaasConfig", max_seq_len: int,
                    prefill_len: int = 0) -> int:
        return self.budget_slots(cfg, prefill_len)

    def refresh_priority(self, cache: "PagedCache", scores: jnp.ndarray,
                         page_probs: jnp.ndarray,
                         cfg: "RaasConfig") -> "PagedCache":
        sel = raas_selected_mask(scores, cache.valid_pages(), cfg)
        now = cache.cur_len.astype(jnp.float32)[:, None]
        return cache._replace(priority=jnp.where(sel, now, cache.priority))
