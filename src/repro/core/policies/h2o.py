"""H2O (heavy-hitter oracle): accumulated-attention-mass eviction.

priority = accumulated *true* attention mass per page; the recent
window is protected.  O(L) slots; ``page_size=1`` recommended (token
granularity, as in the paper's description).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax.numpy as jnp

from repro.core.policy_base import SparsityPolicy, register_policy

if TYPE_CHECKING:
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache


@register_policy("h2o")
class H2OPolicy(SparsityPolicy):
    """O(L) memory; heavy-hitter accumulation + protected recent window."""

    uses_page_probs = True

    def cache_slots(self, cfg: "RaasConfig", max_seq_len: int,
                    prefill_len: int = 0) -> int:
        return self.budget_slots(cfg, prefill_len)

    def refresh_priority(self, cache: "PagedCache", scores: jnp.ndarray,
                         page_probs: jnp.ndarray,
                         cfg: "RaasConfig") -> "PagedCache":
        valid = cache.valid_pages()
        return cache._replace(
            priority=cache.priority + jnp.where(valid, page_probs, 0.0))

    def new_page_priority(self, cache: "PagedCache",
                          cfg: "RaasConfig") -> jnp.ndarray:
        # zero mass so far; protected by the recent window instead.
        return jnp.zeros_like(cache.cur_len, jnp.float32)

    def protect_recent(self, cfg: "RaasConfig") -> int:
        return cfg.h2o_recent
