"""Built-in KV-sparsity policies, one file per policy.

This package is the registry's built-in population: importing it (which
:func:`repro.core.policy_base.get_policy` does lazily) registers every
module below.  The paper's "impossible trinity" (accuracy / O(L) time /
O(L) memory), as the registered set spans it:

    ============  =======  ========  ==================================
    id            time     memory    dynamics
    ============  =======  ========  ==================================
    dense         O(N)     O(N)      attends everything (baseline)
    quest         O(L)     O(N)      top-k page selection, no eviction
    raas          O(L)     O(L)      timestamp refresh, argmin eviction
    h2o           O(L)     O(L)      accumulated-mass eviction + window
    streaming     O(L)     O(L)      frozen priorities = sliding window
    quest_raas    O(k+L)   O(Npre+L) Quest over prefill, RaaS over decode
    ============  =======  ========  ==================================

Adding a policy
===============
Drop one file into this directory (or any imported module)::

    from repro.core.policy_base import SparsityPolicy, register_policy

    @register_policy("my_policy")
    class MyPolicy(SparsityPolicy):
        def cache_slots(self, cfg, max_seq_len, prefill_len=0):
            return self.budget_slots(cfg, prefill_len)   # O(L)
        def refresh_priority(self, cache, scores, page_probs, cfg):
            ...                                          # your dynamics

Nothing else changes: ``RaasConfig(policy="my_policy")`` validates
against the registry, ``decode_attend`` / the serving engine / the
benchmarks dispatch through the object.  If the file lives outside
this package, import it once before building configs.

The module-level functions below are convenience wrappers that resolve
``cfg.policy`` through the registry — the hot path holds the policy
object directly and never re-resolves per step.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

import jax.numpy as jnp

from repro.core.policy_base import (PolicyStats, SparsityPolicy,
                                    available_policies, get_policy,
                                    register_policy)
# importing the modules registers the built-ins
from repro.core.policies import (dense, h2o, quest, quest_raas,  # noqa: F401
                                 raas, streaming)
from repro.core.policies.raas import raas_selected_mask

if TYPE_CHECKING:
    from repro.config import RaasConfig
    from repro.core.paged_cache import PagedCache

__all__ = [
    "PolicyStats", "SparsityPolicy", "available_policies", "get_policy",
    "register_policy", "raas_selected_mask", "cache_slots", "select_pages",
    "refresh_priority", "new_page_priority", "protect_recent_tokens",
    "sink_pin_below",
]


def cache_slots(cfg: "RaasConfig", max_seq_len: int,
                prefill_len: int = 0) -> int:
    return get_policy(cfg.policy).cache_slots(cfg, max_seq_len, prefill_len)


def select_pages(cache: "PagedCache", scores: jnp.ndarray,
                 cfg: "RaasConfig") -> Optional[jnp.ndarray]:
    return get_policy(cfg.policy).select_pages(cache, scores, cfg)


def refresh_priority(cache: "PagedCache", scores: jnp.ndarray,
                     page_probs: jnp.ndarray,
                     cfg: "RaasConfig") -> "PagedCache":
    return get_policy(cfg.policy).refresh_priority(cache, scores,
                                                   page_probs, cfg)


def new_page_priority(cache: "PagedCache", cfg: "RaasConfig") -> jnp.ndarray:
    return get_policy(cfg.policy).new_page_priority(cache, cfg)


def protect_recent_tokens(cfg: "RaasConfig") -> int:
    return get_policy(cfg.policy).protect_recent(cfg)


def sink_pin_below(cache_has_prefill: bool, cfg: "RaasConfig") -> int:
    return get_policy(cfg.policy).sink_pin(cache_has_prefill, cfg)
