"""StreamingLLM: sliding window + pinned sinks over an O(L) cache.

priority = arrival order, never refreshed -> evicting argmin priority
is a sliding window over decode pages; prefill (or, prompt-less, the
first ``sink_tokens`` positions) is pinned as the attention sink.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.core.policy_base import SparsityPolicy, register_policy

if TYPE_CHECKING:
    from repro.config import RaasConfig


@register_policy("streaming")
class StreamingPolicy(SparsityPolicy):
    """O(L) memory; frozen arrival-order priorities."""

    def cache_slots(self, cfg: "RaasConfig", max_seq_len: int,
                    prefill_len: int = 0) -> int:
        return self.budget_slots(cfg, prefill_len)

    def sink_pin(self, has_prefill: bool, cfg: "RaasConfig") -> int:
        # prefill pages are pinned anyway; extra sinks only for the
        # no-prefill corner.
        return 0 if has_prefill else cfg.sink_tokens
