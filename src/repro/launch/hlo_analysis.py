"""Back-compat shim: the HLO passes grew into a framework and moved to
:mod:`repro.analysis.hlo` (collective accounting + roofline here began
as launch-time helpers; the analysis package added KV-copy,
host-transfer, donation and jit-cache passes on top).

Launch-time callers (``launch/dryrun.py``) and older tests import
through this module; new code should import :mod:`repro.analysis.hlo`
directly.
"""
from __future__ import annotations

from repro.analysis.hlo import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    _group_size,
    _shape_bytes,
    collective_bytes,
    count_collectives,
    roofline_terms,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS_BF16",
    "collective_bytes", "count_collectives", "roofline_terms",
]
