"""Parse collective traffic and roofline terms out of compiled HLO.

``collective_bytes`` scans the optimized (post-SPMD) HLO text for
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, reconstructs per-device link traffic from the
result shape and the replica-group size, and returns totals per
collective kind.

Ring-model bytes-on-the-wire per device, for group size g and result
payload R bytes:
  all-gather          (g-1)/g * R        (R is the gathered result)
  all-reduce          2*(g-1)/g * R      (reduce-scatter + all-gather)
  reduce-scatter      (g-1) * R          (R is the scattered result)
  all-to-all          (g-1)/g * R
  collective-permute  R
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return max(1, int(m.group(2)))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device link bytes by collective kind + 'total'."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[^=]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        payload = _shape_bytes(shape_str)
        g = _group_size(s)
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            traffic = payload * (g - 1) / g
        elif kind == "all-reduce":
            traffic = payload * 2 * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = payload * (g - 1)
        elif kind == "all-to-all":
            traffic = payload * (g - 1) / g
        else:
            traffic = payload
        out[kind] += traffic
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for kind in _COLLECTIVES:
        counts[kind] = len(re.findall(rf"\b{kind}(?:-start)?\(", hlo_text))
    return counts


# v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    return {
        "compute_s": flops_per_device / PEAK_FLOPS_BF16,
        "memory_s": bytes_per_device / HBM_BW,
        "collective_s": coll_bytes_per_device / ICI_BW,
    }
