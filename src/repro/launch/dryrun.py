"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be run as its own process (``python -m repro.launch.dryrun``):
the first two lines below force 512 host placeholder devices before
any jax initialization — smoke tests and benchmarks must NOT import
this module (they need the real 1-device platform).

For each combination this program:
  1. builds ShapeDtypeStruct stand-ins for every input (no allocation),
  2. jits the step (train_step / prefill / serve_step) with explicit
     NamedShardings from launch/shardings.py,
  3. ``.lower(...)``, ``.compile()`` — failures here are bugs,
  4. records memory_analysis / cost_analysis / per-device collective
     bytes (parsed from the optimized HLO) into a JSON report consumed
     by EXPERIMENTS.md §Dry-run and the roofline benchmark.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (INPUT_SHAPES, ModelConfig, RaasConfig,  # noqa: E402
                          RunConfig, get_config, list_archs)
from repro.analysis import hlo as hlo_analysis  # noqa: E402
from repro.launch import mesh as mesh_lib, shardings  # noqa: E402
from repro.launch.train import make_train_step  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim import adamw  # noqa: E402

# decode-shape sparsity defaults: the paper's technique (RaaS) with a
# 4k-token budget; the dense baseline is lowered separately.
DECODE_BUDGET = 4096
PREFILL_FOR_DECODE = 128     # paper: short prefill (math question)


def spec_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape_name: str, policy: str,
                dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    seq, batch, kind = INPUT_SHAPES[shape_name]
    sds = jax.ShapeDtypeStruct
    out: Dict = {"kind": kind, "batch": batch, "seq": seq}
    tok_shape = (batch, seq) if cfg.n_codebooks == 1 \
        else (batch, seq, cfg.n_codebooks)
    if kind == "train":
        out["batch_inputs"] = {
            "tokens": sds(tok_shape, jnp.int32),
            "loss_mask": sds((batch, seq), jnp.float32),
        }
        if cfg.frontend:
            out["batch_inputs"]["prefix_emb"] = sds(
                (batch, cfg.n_prefix_tokens, cfg.d_model), dtype)
    elif kind == "prefill":
        out["tokens"] = sds(tok_shape, jnp.int32)
        out["lengths"] = sds((batch,), jnp.int32)
        if cfg.frontend:
            out["prefix_emb"] = sds(
                (batch, cfg.n_prefix_tokens, cfg.d_model), dtype)
    else:  # decode
        tok = (batch,) if cfg.n_codebooks == 1 else (batch, cfg.n_codebooks)
        out["token"] = sds(tok, jnp.int32)
        out["pos"] = sds((batch,), jnp.int32)
    return out


def raas_for(cfg: ModelConfig, shape_name: str, policy: str) -> RaasConfig:
    seq, _, kind = INPUT_SHAPES[shape_name]
    return RaasConfig(policy=policy, budget_tokens=DECODE_BUDGET,
                      page_size=16)


def apply_opts(cfg: ModelConfig, opts: Tuple[str, ...]) -> ModelConfig:
    """Named beyond-baseline optimizations (§Perf hillclimbing levers)."""
    if "moe_shard" in opts and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, dispatch_axes=("model", "data", None)))
    return cfg


def build(cfg: ModelConfig, shape_name: str, mesh, multi_pod: bool,
          policy: str, dtype=jnp.bfloat16, fsdp: bool = True,
          opts: Tuple[str, ...] = ()):
    """Returns (fn, args_specs, in_shardings) ready for jit/lower."""
    cfg = apply_opts(cfg, opts)
    opt_dtype = jnp.bfloat16 if "bf16_moments" in opts else jnp.float32
    seq, batch, kind = INPUT_SHAPES[shape_name]
    baxes = mesh_lib.batch_axes(multi_pod)
    params_spec = jax.eval_shape(
        lambda k: M.init_params(k, cfg, dtype), jax.random.PRNGKey(0))

    if kind == "train":
        run = RunConfig(arch=cfg.name, shape=shape_name)
        step = make_train_step(cfg, run, impl="jnp")
        pshard = shardings.params_shardings(params_spec, cfg, mesh,
                                            "train", fsdp=fsdp)
        opt_spec = jax.eval_shape(
            lambda p: adamw.init(p, opt_dtype), params_spec)
        # optimizer moments follow the param layout
        mu_shard = jax.tree.map(
            lambda s: s, pshard)
        opt_shard = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=mu_shard, nu=jax.tree.map(lambda s: s, pshard))
        ins = input_specs(cfg, shape_name, policy, dtype)
        bshard = {
            k: shardings.batch_sharding(mesh, batch, baxes, v.ndim)
            for k, v in ins["batch_inputs"].items()}
        fn = step
        args = (params_spec, opt_spec, ins["batch_inputs"])
        in_sh = (pshard, opt_shard, bshard)
        return fn, args, in_sh

    # serving shapes
    raas = raas_for(cfg, shape_name, policy)
    n_prefix = cfg.n_prefix_tokens if cfg.frontend else 0
    if kind == "prefill":
        # prefill ingestion is policy-agnostic; cache sized O(N) (dense)
        raas = dataclasses.replace(raas, policy="dense")
        prefill_len = seq + n_prefix
        max_seq = seq + n_prefix + 1
    else:
        prefill_len = PREFILL_FOR_DECODE + n_prefix
        max_seq = seq + n_prefix

    cache_spec_tree = jax.eval_shape(
        lambda: M.init_model_cache(cfg, raas, batch, max_seq,
                                   prefill_len=prefill_len, dtype=dtype))
    cshard = shardings.cache_shardings(cache_spec_tree, batch, mesh, baxes)
    # "decode_2d" (§Perf): spread decode weights over the data axis too
    # (2D tensor parallelism) — at tiny per-step batch the decode step
    # is bound by reading resident params, so 16x more shards = 16x
    # less HBM traffic per device, paid with small activation
    # all-gathers.
    pshard = shardings.params_shardings(params_spec, cfg, mesh, "decode",
                                        fsdp="decode_2d" in opts)
    ins = input_specs(cfg, shape_name, policy, dtype)

    if kind == "prefill":
        def fn(params, cache, tokens, lengths, prefix_emb=None):
            return M.prefill(params, cfg, tokens, lengths, cache,
                             prefix_emb=prefix_emb, impl="jnp")
        args = [params_spec, cache_spec_tree, ins["tokens"],
                ins["lengths"]]
        in_sh = [pshard, cshard,
                 shardings.batch_sharding(mesh, batch, baxes, 2),
                 shardings.batch_sharding(mesh, batch, baxes, 1)]
        if cfg.frontend:
            args.append(ins["prefix_emb"])
            in_sh.append(shardings.batch_sharding(mesh, batch, baxes, 3))
        return fn, tuple(args), tuple(in_sh)

    def fn(params, cache, token, pos):
        return M.decode_step(params, cfg, token, pos, cache, raas,
                             impl="jnp")
    args = (params_spec, cache_spec_tree, ins["token"], ins["pos"])
    in_sh = (pshard, cshard,
             shardings.batch_sharding(mesh, batch, baxes,
                                      ins["token"].ndim),
             shardings.batch_sharding(mesh, batch, baxes, 1))
    return fn, args, in_sh


def should_skip(cfg: ModelConfig, shape_name: str,
                policy: str) -> Optional[str]:
    seq, batch, kind = INPUT_SHAPES[shape_name]
    if kind == "decode" and cfg.attn_free and policy != "dense":
        return ("attention-free SSM: no KV cache exists; RaaS "
                "inapplicable (DESIGN.md §Arch-applicability) — lowered "
                "with native O(1) state instead")
    if shape_name == "long_500k" and policy == "dense" \
            and cfg.has_attention:
        return ("long_500k with dense O(N) attention cache is the "
                "workload the paper replaces; lowered under RaaS O(L) "
                "instead (DESIGN.md §4)")
    return None


def _metrics(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    # older jax returned a one-element list of dicts; newer returns the
    # dict directly — accept both.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        **{f"coll_{k}": v for k, v in coll.items()},
    }


def corrected_costs(cfg: ModelConfig, shape_name: str, mesh,
                    multi_pod: bool, policy: str,
                    opts: Tuple[str, ...]) -> Dict[str, float]:
    """Depth-extrapolated per-device costs.

    XLA's HloCostAnalysis (and text-level collective parsing) count a
    while-loop body ONCE regardless of trip count, so the full-depth
    scanned program under-reports everything inside the layer scan by
    ~n_periods x.  Cost is affine in depth — cost(n) = a + b*n — so we
    compile fully-UNROLLED 1- and 2-period variants (cheap: same global
    shapes, tiny stacks), fit (a, b), and evaluate at the real depth.
    The full-depth compile (run_one) remains the sharding/memory proof.
    """
    from repro.models import model as M_mod

    per = len(cfg.period)
    ms = []
    M_mod.SCAN_UNROLL[0] = True
    try:
        for n in (1, 2):
            cfg_n = dataclasses.replace(cfg, n_layers=per * n)
            fn, args, in_sh = build(cfg_n, shape_name, mesh, multi_pod,
                                    policy, opts=opts)
            with mesh:
                compiled = jax.jit(fn, in_shardings=in_sh).lower(
                    *args).compile()
            ms.append(_metrics(compiled))
    finally:
        M_mod.SCAN_UNROLL[0] = False
    n_p = cfg.n_periods
    out = {}
    for k in ms[0]:
        b = ms[1][k] - ms[0][k]
        out[k] = ms[0][k] + b * (n_p - 1)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, policy: str,
            out_path: Optional[str] = None,
            opts: Tuple[str, ...] = ()) -> Dict:
    cfg = get_config(arch)
    seq, batch, kind = INPUT_SHAPES[shape_name]
    rec: Dict = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy if kind == "decode" else
        ("dense" if kind != "train" else "n/a"),
        "opts": list(opts),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    skip = should_skip(cfg, shape_name, policy)
    if skip and kind == "decode" and cfg.attn_free:
        rec["policy"] = "native-ssm"
        policy = "dense"  # cache is empty of attention state anyway
        rec["note"] = skip
    elif skip:
        rec["policy"] = "raas"
        policy = "raas"
        rec["note"] = skip

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    fn, args, in_sh = build(cfg, shape_name, mesh, multi_pod, policy,
                            opts=opts)
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_sh)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    counts = hlo_analysis.count_collectives(hlo)
    raw = _metrics(compiled)

    # depth-corrected per-device costs (see corrected_costs docstring)
    corr = corrected_costs(cfg, shape_name, mesh, multi_pod, policy,
                           opts)
    flops_total = corr["flops"]
    bytes_total = corr["bytes"]
    coll = {k[len("coll_"):]: v for k, v in corr.items()
            if k.startswith("coll_")}
    terms = hlo_analysis.roofline_terms(flops_total, bytes_total,
                                        coll["total"])
    rec.update({
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_total,
        "bytes_per_device": bytes_total,
        "collective_bytes_per_device": coll["total"],
        "collective_breakdown": coll,
        "collective_counts": counts,
        "raw_hlo_once": raw,   # uncorrected (loop body counted once)
        "roofline": terms,
        "dominant": max(terms, key=terms.get),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes",
                                           0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "status": "ok",
    })
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m",
                   choices=list(list_archs()))
    p.add_argument("--shape", default="train_4k",
                   choices=list(INPUT_SHAPES))
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--policy", default="raas",
                   help="decode-shape policy (raas|dense|quest)")
    p.add_argument("--opts", default="",
                   help="comma list of perf levers: moe_shard,"
                        "bf16_moments")
    p.add_argument("--out", default="")
    args = p.parse_args(argv)

    opts = tuple(o for o in args.opts.split(",") if o)
    rec = run_one(args.arch, args.shape, args.mesh == "multi",
                  args.policy, args.out or None, opts=opts)
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
