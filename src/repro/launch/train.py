"""Distributed training driver: train_step factory + CLI loop.

``make_train_step`` builds the jittable (params, opt, batch) -> update
closure used by the CLI here, the dry-run lowering, the smoke tests and
the end-to-end example — one definition everywhere.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig, get_config
from repro.models import model as M
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    impl: str = "jnp", capacity_factor: float = 1.25):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    batch: {"tokens": [B, T(,C)] i32, "loss_mask": [B, T] f32,
            optional "prefix_emb": [B, n_prefix, D]}.
    """
    n_prefix = cfg.n_prefix_tokens if cfg.frontend else 0

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        mask = batch["loss_mask"]
        prefix = batch.get("prefix_emb")

        def loss_f(p):
            logits, aux = M.forward_train(
                p, cfg, tokens, prefix_emb=prefix, impl=impl,
                remat=run.remat, capacity_factor=capacity_factor)
            logits = logits[:, n_prefix:]
            loss = M.loss_fn(logits[:, :-1], tokens[:, 1:], mask[:, 1:])
            return loss + aux, (loss, aux)

        grads, (loss, aux) = jax.grad(loss_f, has_aux=True)(params)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.clip_norm)
        lr = adamw.cosine_schedule(opt_state.step, run.lr,
                                   run.warmup_steps, run.total_steps)
        params, opt_state = adamw.update(params, grads, opt_state, lr,
                                         weight_decay=run.weight_decay)
        metrics = {"loss": loss, "aux": aux, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, impl: str = "jnp"):
    n_prefix = cfg.n_prefix_tokens if cfg.frontend else 0

    def eval_step(params, batch):
        logits, _ = M.forward_train(params, cfg, batch["tokens"],
                                    prefix_emb=batch.get("prefix_emb"),
                                    impl=impl, remat=False)
        logits = logits[:, n_prefix:]
        return M.loss_fn(logits[:, :-1], batch["tokens"][:, 1:],
                         batch["loss_mask"][:, 1:])

    return eval_step


def main(argv=None) -> None:
    from repro.data.pipeline import DataConfig, batches

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--reduced", action="store_true",
                   help="train the reduced (smoke) variant of --arch")
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt", default="")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(arch=args.arch, lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 10))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq)

    params = M.init_params(jax.random.PRNGKey(run.seed), cfg)
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, run))

    it = batches(dc, args.batch)
    t0 = time.time()
    for step in range(args.steps):
        b = next(it)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "loss_mask": jnp.asarray(b["loss_mask"])}
        if cfg.frontend:
            batch["prefix_emb"] = jnp.zeros(
                (args.batch, cfg.n_prefix_tokens, cfg.d_model))
        if cfg.n_codebooks > 1:
            batch["tokens"] = jnp.repeat(batch["tokens"][..., None],
                                         cfg.n_codebooks, axis=-1)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['gnorm']):.3f} lr {float(m['lr']):.2e} "
                  f"({time.time() - t0:.1f}s)")
    if args.ckpt:
        from repro.checkpoint import ckpt
        ckpt.save(f"{args.ckpt}/{args.steps}.msgpack",
                  {"params": params})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
