"""PartitionSpec rules for every parameter / state leaf, per arch.

Megatron-style tensor parallelism on the "model" axis with an FSDP-
style secondary shard on the "data" axis (largest remaining divisible
dim), applied by *name suffix* rules over the params pytree.  Block
parameters carry a leading [n_periods] scan-stack dim which the rules
skip automatically.

Three modes:
  * "train"  — attention projections sharded on the *head* dim where
    divisible (column-parallel QKV / row-parallel O), else row-parallel
    on d_model.
  * "decode" — attention projections and the paged KV cache sharded on
    *head_dim* (hd is a multiple of 16 for every assigned arch, unlike
    head counts), so the decode cache memory splits across the model
    axis without gather traffic on the page dim.
  * "engine" — the serving engine's mesh mode.  Params shard exactly
    per the "decode" rule table; what is new is the *engine state*: the
    lane (batch) axis of the paged cache, the lane phase/progress
    tables and the decode token buffers all shard across the "data"
    axis (KV pages are lane-major page-major ``[B, KV, S, P, hd]``, so
    they shard on axis 0 — axis 1 of the period-stacked cache leaves),
    keeping per-device KV at O(L * B / n_data) while every dispatch
    stays a single jitted computation under the mesh
    (:func:`lane_sharding` / :func:`engine_state_shardings`).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_str(path) -> str:
    """'/'-joined key path of a tree leaf.

    Handles every jax key type by field: DictKey (.key), GetAttrKey
    (.name — NamedTuple fields like the paged cache's ``k_pages``; its
    ``str()`` is ".k_pages", which used to defeat the name-match rules
    silently), SequenceKey (.idx).
    """
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return "/".join(keys)


def _with_fsdp(spec: list, shape: Tuple[int, ...], data_size: int,
               fsdp: bool) -> list:
    """Assign ("data",) to the largest unsharded dim divisible by data."""
    if not fsdp or "data" in spec:
        return spec
    cands = [(shape[i], i) for i in range(len(shape))
             if spec[i] is None and _divisible(shape[i], data_size)]
    if cands:
        _, i = max(cands)
        spec[i] = "data"
    return spec


def param_pspec(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                mode: str, model_size: int, data_size: int,
                fsdp: bool = False) -> P:
    """Rule table.  ``path`` is '/'-joined key path of the leaf."""
    if mode not in ("train", "decode", "engine"):
        raise ValueError(f"unknown sharding mode {mode!r}")
    if mode == "engine":
        # serving under a mesh: params follow the decode rule table —
        # the engine-specific sharding lives in the *state* rules below.
        mode = "decode"
    name = path.split("/")[-1]
    # strip scan-stack leading dim for blocks
    stacked = path.startswith("blocks")
    base_shape = shape[1:] if stacked else shape
    nd = len(base_shape)
    spec: list = [None] * nd
    m = model_size

    def set_if(i, size):
        if _divisible(size, m):
            spec[i] = "model"
            return True
        return False

    if name == "embed":                      # [C, V, D]
        set_if(1, base_shape[1]) or set_if(2, base_shape[2])
    elif name == "lm_head":                  # [D, C, V]
        set_if(2, base_shape[2]) or set_if(0, base_shape[0])
    elif name in ("wq", "wk", "wv"):         # [D, H|KV, hd]
        if mode == "decode":
            set_if(2, base_shape[2]) or set_if(0, base_shape[0])
        else:
            set_if(1, base_shape[1]) or set_if(0, base_shape[0])
    elif name == "wo":                       # [H, hd, D]
        if mode == "decode":
            set_if(1, base_shape[1]) or set_if(2, base_shape[2])
        else:
            set_if(0, base_shape[0]) or set_if(2, base_shape[2])
    elif name in ("w_gate", "w_up"):
        if nd == 2:                          # dense ffn [D, F]
            set_if(1, base_shape[1])
        else:                                # moe [E, D, F]
            set_if(0, base_shape[0]) or set_if(2, base_shape[2])
            # FSDP on the hidden dim F (column-parallel w.r.t. the
            # dispatch buffer) — sharding D instead collides with the
            # [E, C:data, D] dispatch layout and forces full-buffer
            # all-gathers (§Perf kimi it1, refuted hypothesis).
            if fsdp and spec[2] is None and _divisible(base_shape[2],
                                                       data_size):
                spec[2] = "data"
    elif name == "w_down":
        if nd == 2:                          # [F, D]
            set_if(0, base_shape[0])
        else:                                # [E, F, D]
            set_if(0, base_shape[0]) or set_if(1, base_shape[1])
            if fsdp and spec[1] is None and _divisible(base_shape[1],
                                                       data_size):
                spec[1] = "data"             # row-parallel on F
    elif name == "router":                   # [D, E]
        set_if(1, base_shape[1])
    elif name in ("in_z", "in_x"):           # [D, d_in]
        set_if(1, base_shape[1])
    elif name == "in_dt":                    # [D, H]
        set_if(1, base_shape[1])
    elif name == "out_proj":                 # [d_in, D]
        set_if(0, base_shape[0])
    elif name in ("conv_x_w", "conv_x_b"):   # [d_conv, d_in] / [d_in]
        set_if(nd - 1, base_shape[-1])
    elif name in ("A_log", "D_skip", "dt_bias"):  # [H]
        set_if(0, base_shape[0])
    elif name == "scale" and "mamba" in path and "norm" in path:
        set_if(0, base_shape[0])             # [d_in] matches hidden shard
    # everything else (norms, in_B/in_C, conv_B/C, biases): replicated

    spec = _with_fsdp(spec, base_shape, data_size, fsdp)
    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_shardings(params, cfg: ModelConfig, mesh: Mesh, mode: str,
                     fsdp: bool = False):
    """Tree of NamedShardings matching ``params``."""
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"]

    def one(path, leaf):
        ps = param_pspec(_path_str(path), leaf.shape, cfg, mode,
                         model_size, data_size, fsdp)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Cache / activation shardings (decode)
# ---------------------------------------------------------------------------
def cache_pspec(path: str, shape: Tuple[int, ...], batch: int,
                batch_axes: Tuple[str, ...], mesh: Mesh,
                model_size: int) -> P:
    """Paged-cache and mamba-state leaves.

    Leaves carry a leading [n_periods] stack dim, then batch.  KV pages
    [.., B, KV, S, P, hd] (page-major kernel-native layout) shard batch
    over data axes and hd over model; mamba ssm [.., B, H, P, N] shards
    heads over model.
    """
    name = path.split("/")[-1]
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    bspec = batch_axes if _divisible(batch, bsz) else None
    nd = len(shape)
    spec: list = [None] * nd
    # leaves are stacked [n_periods, B, ...] — batch is dim 1
    if nd >= 2:
        spec[1] = bspec
    if name in ("k_pages", "v_pages", "rep_min", "rep_max") \
            and _divisible(shape[-1], model_size):
        spec[-1] = "model"                   # head_dim
    elif name == "ssm" and nd >= 3 and _divisible(shape[2], model_size):
        spec[2] = "model"                    # heads
    elif name == "conv_x" and _divisible(shape[-1], model_size):
        spec[-1] = "model"                   # d_inner
    return P(*spec)


def cache_shardings(cache, batch: int, mesh: Mesh,
                    batch_axes: Tuple[str, ...]):
    model_size = mesh.shape["model"]

    def one(path, leaf):
        ps = cache_pspec(_path_str(path), leaf.shape, batch, batch_axes,
                         mesh, model_size)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_sharding(mesh: Mesh, batch: int, batch_axes: Tuple[str, ...],
                   ndim: int) -> NamedSharding:
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    spec = [batch_axes if batch % bsz == 0 else None] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# Engine-state shardings (serving under a mesh)
# ---------------------------------------------------------------------------
def lane_pspec(batch: int, data_size: int, ndim: int = 1,
               lane_axis: int = 0) -> P:
    """Engine per-lane state: shard the lane axis over "data".

    Covers every flat engine buffer — [B] token / position / phase /
    progress / budget tables, [B, C] prefill token chunks, [B, V]
    last-position logits, and the [K, B] per-step outputs of the fused
    decode chunk (``lane_axis=1``).  Falls back to replicated when the
    lane count does not divide the data axis.
    """
    spec: list = [None] * ndim
    if _divisible(batch, data_size):
        spec[lane_axis] = "data"
    return P(*spec)


def lane_sharding(mesh: Mesh, batch: int, ndim: int = 1,
                  lane_axis: int = 0) -> NamedSharding:
    """NamedSharding form of :func:`lane_pspec`."""
    return NamedSharding(
        mesh, lane_pspec(batch, mesh.shape["data"], ndim, lane_axis))


def engine_state_shardings(cache, batch: int, mesh: Mesh):
    """Shardings for the engine's device-resident cache state.

    The paged cache (and SSM state, for hybrid archs) shards its lane
    axis over "data" and — where divisible — head_dim / heads over
    "model", exactly the :func:`cache_pspec` decode rules with the
    engine's single-host batch axes.  ``cache`` may be a pytree of
    arrays *or* of ShapeDtypeStructs (``jax.eval_shape`` output), so
    the engine can jit its cache init with these as ``out_shardings``
    and never materialize an unsharded cache on one device.
    """
    return cache_shardings(cache, batch, mesh, ("data",))
