"""Sequential driver for the full dry-run matrix.

Spawns one subprocess per (arch x shape x mesh [x policy]) so each run
gets a fresh jax with 512 forced host devices.  Writes one JSON per
combo under experiments/dryrun/ and a rolling summary CSV.

Order: all 40 single-pod baselines first (the roofline table), then the
40 multi-pod proofs, then dense-baseline decode variants.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "smollm-360m", "olmoe-1b-7b", "mamba2-780m", "musicgen-medium",
    "paligemma-3b", "qwen25-math-7b", "qwen3-8b", "internlm2-20b",
    "yi-34b", "jamba-1.5-large-398b", "kimi-k2-1t-a32b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def combos(include_extras: bool):
    for mesh in ("single", "multi"):
        for arch in ARCHS:
            for shape in SHAPES:
                yield arch, shape, mesh, "raas"
    if include_extras:
        # dense decode baselines (paper comparison rows), single-pod
        for arch in ARCHS:
            yield arch, "decode_32k", "single", "dense"
            yield arch, "decode_32k", "single", "quest"


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--outdir", default="experiments/dryrun")
    p.add_argument("--timeout", type=int, default=3600)
    p.add_argument("--extras", action="store_true")
    p.add_argument("--only-missing", action="store_true", default=True)
    args = p.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    results = []
    for arch, shape, mesh, policy in combos(args.extras):
        tag = f"{arch}_{shape}_{mesh}" + (
            f"_{policy}" if policy != "raas" else "")
        out = os.path.join(args.outdir, tag + ".json")
        if args.only_missing and os.path.exists(out):
            with open(out) as f:
                rec = json.load(f)
            results.append(rec)
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--policy", policy, "--out", out]
        print(f"[{time.strftime('%H:%M:%S')}] {tag} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                rec = {"arch": arch, "shape": shape, "mesh": mesh,
                       "policy": policy, "status": "FAIL",
                       "error": r.stderr[-2000:]}
                with open(out, "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"   FAIL ({time.time()-t0:.0f}s): "
                      f"{r.stderr.splitlines()[-1] if r.stderr else '?'}",
                      flush=True)
            else:
                with open(out) as f:
                    rec = json.load(f)
                print(f"   ok ({time.time()-t0:.0f}s) "
                      f"dominant={rec.get('dominant')}", flush=True)
        except subprocess.TimeoutExpired:
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "policy": policy, "status": "TIMEOUT"}
            with open(out, "w") as f:
                json.dump(rec, f, indent=2)
            print(f"   TIMEOUT ({args.timeout}s)", flush=True)
        results.append(rec)

    # summary CSV
    with open(os.path.join(args.outdir, "summary.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["arch", "shape", "mesh", "policy", "status",
                    "compile_s", "flops_per_device", "bytes_per_device",
                    "coll_bytes_per_device", "compute_s", "memory_s",
                    "collective_s", "dominant"])
        for r in results:
            t = r.get("roofline", {})
            w.writerow([r.get("arch"), r.get("shape"), r.get("mesh"),
                        r.get("policy"), r.get("status"),
                        r.get("compile_s"), r.get("flops_per_device"),
                        r.get("bytes_per_device"),
                        r.get("collective_bytes_per_device"),
                        t.get("compute_s"), t.get("memory_s"),
                        t.get("collective_s"), r.get("dominant")])
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"done: {n_ok}/{len(results)} ok")


if __name__ == "__main__":
    main()
