"""Production mesh construction (TPU v5e 16x16 pod; 2-pod multi-pod).

``make_production_mesh`` is a FUNCTION (not a module constant) so
importing this module never touches jax device state — the dry-run
must set XLA_FLAGS before any jax initialization.  The same rule holds
for the serving meshes: build them *after* process start-up has had its
chance to set ``--xla_force_host_platform_device_count`` (tests) or
select real accelerators (deployment).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str) -> Tuple[Tuple[str, int], ...]:
    """Parse a ``"data=4"`` / ``"data=2,model=2"`` mesh spec string.

    Pure string processing (no jax device access) so configs and CLIs
    can validate a spec without initializing the backend.  Axis order
    in the string is mesh axis order; ``data`` must be present (the
    engine shards lanes over it) and ``model`` is implied with size 1
    when omitted.
    """
    axes: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, size = part.partition("=")
        name = name.strip()
        if not name or not size or name in axes:
            raise ValueError(f"bad mesh spec {spec!r}: expected unique "
                             "'axis=N' entries, e.g. 'data=4,model=2'")
        try:
            axes[name] = int(size)
        except ValueError:
            raise ValueError(f"bad mesh spec {spec!r}: size of axis "
                             f"{name!r} is not an integer") from None
        if axes[name] < 1:
            raise ValueError(f"bad mesh spec {spec!r}: axis sizes must "
                             "be positive")
    if "data" not in axes:
        raise ValueError(f"mesh spec {spec!r} has no 'data' axis — the "
                         "serving engine shards lanes over 'data'")
    axes.setdefault("model", 1)
    return tuple(axes.items())


def make_serving_mesh(spec: str = "", *, data: int = 0, model: int = 1):
    """Mesh for the sharded serving engine.

    Either parse ``spec`` ("data=4" / "data=2,model=2") or take explicit
    axis sizes.  Raises with a hint about forced host devices when the
    process does not expose enough devices — the mesh itself is always
    ("data", "model")-shaped so :mod:`repro.launch.shardings` engine
    rules apply verbatim.
    """
    if spec:
        axes = dict(parse_mesh_spec(spec))
        data, model = axes.pop("data"), axes.pop("model")
        if axes:
            raise ValueError(f"serving mesh supports axes data/model, "
                             f"got extra {sorted(axes)} in {spec!r}")
    if data < 1:
        raise ValueError("serving mesh needs data >= 1 (pass spec or data=)")
    n_need = data * model
    n_have = jax.device_count()
    if n_need > n_have:
        raise ValueError(
            f"serving mesh data={data},model={model} needs {n_need} "
            f"devices but only {n_have} are visible (on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_need} "
            "before jax initializes)")
    return jax.make_mesh((data, model), ("data", "model"))
