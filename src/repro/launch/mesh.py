"""Production mesh construction (TPU v5e 16x16 pod; 2-pod multi-pod).

``make_production_mesh`` is a FUNCTION (not a module constant) so
importing this module never touches jax device state — the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
