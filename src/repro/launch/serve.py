"""Serving driver: batched requests through the RaaS engine (CLI).

Runs the synthetic reasoning workload (short math-style prompts, long
verifiable chains) through the continuous-batching engine under a
chosen sparsity policy, reporting JCT, throughput, accuracy and KV
memory — the deployment-shaped counterpart of the paper's §4 setup.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RaasConfig, ServeConfig, get_config
from repro.core.policy_base import available_policies
from repro.data.pipeline import DataConfig, prompt_of, specials, verify_answer
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import serve


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--policy", default="raas",
                   choices=list(available_policies()))
    p.add_argument("--budget", type=int, default=128)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=96)
    p.add_argument("--prefill-chunk", type=int, default=16,
                   help="prompt tokens ingested per prefill dispatch")
    p.add_argument("--mesh", default="",
                   help="serving mesh spec, e.g. 'data=4' or "
                        "'data=2,model=2': shards engine lanes (paged "
                        "cache, token buffers) over 'data' and params "
                        "over 'model'; on CPU set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N first")
    p.add_argument("--ckpt", default="",
                   help="optional params checkpoint (msgpack)")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=2, d_model=128, vocab=128)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                    chain_steps=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import ckpt as C
        like = jax.eval_shape(lambda: {"params": params})
        params = C.restore(args.ckpt, like)["params"]

    raas = RaasConfig(policy=args.policy, budget_tokens=args.budget,
                      page_size=16)
    serve_cfg = ServeConfig(batch_slots=args.slots,
                            max_seq=args.max_new + 64, max_prefill=32,
                            prefill_chunk=args.prefill_chunk,
                            mesh=args.mesh)
    eng = Engine(params, cfg, raas, serve_cfg)
    sp = specials(dc)
    reqs = []
    for i in range(args.requests):
        prompt, _ = prompt_of(dc, 10_000 + i)
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=args.max_new,
                            eos_id=sp["EOS"]))
    t0 = time.time()
    done = serve(eng, reqs)
    jct = time.time() - t0
    acc = np.mean([verify_answer(dc, 10_000 + r.uid,
                                 np.asarray(r.output)) for r in done])
    # throughput from the engine's true emitted-token count (device-side
    # mask), not dispatches x chunk length
    mesh_note = f" mesh={args.mesh}" if args.mesh else ""
    print(f"policy={args.policy} budget={args.budget} "
          f"requests={len(done)} JCT={jct:.2f}s "
          f"throughput={eng.tokens_emitted/jct:.1f} tok/s "
          f"accuracy={acc:.2f} "
          f"kv_bytes={eng.kv_cache_bytes()/1e6:.1f}MB "
          f"kv_bytes_per_device={eng.kv_cache_bytes_per_device()/1e6:.1f}MB "
          f"dispatches={eng.dispatches}+{eng.prefill_dispatches}pf"
          f"{mesh_note}")


if __name__ == "__main__":
    main()
