"""repro — RaaS (ACL 2025 Findings) reproduction framework.

The paper's contribution lives in ``repro.core`` (paged KV cache +
sparsity policies + policy-aware decode attention); ``repro.models``
is the 10-architecture zoo, ``repro.launch`` the multi-pod
distribution layer.  See README.md / DESIGN.md.
"""
from repro.config import (ModelConfig, MoEConfig, MambaConfig,
                          RaasConfig, RunConfig, get_config, list_archs)

__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "RaasConfig",
    "RunConfig", "get_config", "list_archs",
]
