"""Public kernel API with implementation dispatch.

Every op takes ``impl``:
  "jnp"               — pure-jnp oracle (CPU fast path; what the
                        distributed dry-run lowers so cost_analysis
                        sees real FLOPs/bytes),
  "pallas_interpret"  — Pallas kernel, interpret mode (CPU-validated),
  "pallas"            — Pallas kernel compiled for TPU (the target).

The Pallas wrappers handle layout (page-major transposes), padding to
block multiples, and the online-softmax page-probability fixup.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

IMPLS = ("jnp", "pallas", "pallas_interpret")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Paged decode attention
# ---------------------------------------------------------------------------
def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, token_mask: jnp.ndarray,
                           scale: float, impl: str = "jnp",
                           block_tokens: int = 512
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q [B,H,hd]; k/v_pages [B,S,P,KV,hd]; token_mask [B,S,P] bool.

    Returns (ctx [B,H,hd], page_probs [B,S] — true probability mass per
    page summed over heads).
    """
    if impl == "jnp":
        return ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                              token_mask, scale)
    from repro.kernels.paged_attention import paged_decode_attention_pallas

    B, H, hd = q.shape
    S, P, KV = k_pages.shape[1:4]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    # page-major token layout [B, KV, T, hd]
    kt = k_pages.reshape(B, S * P, KV, hd).transpose(0, 2, 1, 3)
    vt = v_pages.reshape(B, S * P, KV, hd).transpose(0, 2, 1, 3)
    mask = token_mask.reshape(B, S * P).astype(jnp.float32)

    T = S * P
    bT = min(block_tokens, _round_up(T, P))
    bT = max(P, (bT // P) * P)
    Tp = _round_up(T, bT)
    if Tp != T:
        pad = Tp - T
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    ctx, psums, bmax, ml = paged_decode_attention_pallas(
        qg, kt, vt, mask, scale=scale, page_size=P, block_tokens=bT,
        interpret=(impl == "pallas_interpret"))

    # fixup: true page probs = psum * exp(m_block - m_final) / l_final
    nT = bmax.shape[-1]
    Sp = Tp // P
    pages_per_block = bT // P
    m_final = ml[..., 0:1]                                  # [B,KV,G,1]
    l_final = jnp.maximum(ml[..., 1:2], 1e-30)
    corr = jnp.exp(bmax - m_final)                          # [B,KV,G,nT]
    corr_pages = jnp.repeat(corr, pages_per_block, axis=-1)  # [B,KV,G,Sp]
    probs_g = psums * corr_pages / l_final                  # [B,KV,G,Sp]
    page_probs = probs_g.sum(axis=(1, 2))[:, :S]            # [B,S]
    return ctx.reshape(B, H, hd), page_probs


# ---------------------------------------------------------------------------
# Representative page scoring
# ---------------------------------------------------------------------------
def page_score(q: jnp.ndarray, rep_min: jnp.ndarray, rep_max: jnp.ndarray,
               page_mask: jnp.ndarray, scale: float, impl: str = "jnp",
               block_pages: int = 256) -> jnp.ndarray:
    """q [B,H,hd]; rep_min/max [B,S,KV,hd]; page_mask [B,S] bool.

    Returns scores [B,S] f32 (-inf at invalid pages).
    """
    if impl == "jnp":
        return ref.page_score_ref(q, rep_min, rep_max, page_mask, scale)
    from repro.kernels.page_score import page_score_pallas

    B, H, hd = q.shape
    S, KV = rep_min.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bS = min(block_pages, S)
    Sp = _round_up(S, bS)
    rmin, rmax, mask = rep_min, rep_max, page_mask.astype(jnp.float32)
    if Sp != S:
        pad = Sp - S
        rmin = jnp.pad(rmin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rmax = jnp.pad(rmax, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    out = page_score_pallas(qg, rmin, rmax, mask, scale=scale,
                            block_pages=bS,
                            interpret=(impl == "pallas_interpret"))
    return out[:, :S]


# ---------------------------------------------------------------------------
# Flash prefill
# ---------------------------------------------------------------------------
def flash_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  scale: float, q_offset: int = 0, impl: str = "jnp",
                  block_q: int = 256, block_k: int = 256) -> jnp.ndarray:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> ctx [B,Sq,H,hd] (causal).

    impl "jnp" switches to the memory-bounded scan flash (custom VJP)
    automatically once the kv length would make the naive [Sq, Skv]
    logits tensor the memory bottleneck; "jnp_naive" forces the oracle.
    """
    if impl == "jnp" and k.shape[1] > 1024:
        impl = "jnp_flash"
    if impl == "jnp_flash":
        from repro.kernels.flash_scan import flash_causal
        return flash_causal(q, k, v, scale, q_offset, block_k)
    if impl in ("jnp", "jnp_naive"):
        return ref.flash_prefill_ref(q, k, v, scale, q_offset)
    from repro.kernels.flash_prefill import flash_prefill_pallas

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)                   # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bQ, bK = min(block_q, Sq), min(block_k, Skv)
    Sqp, Skvp = _round_up(Sq, bQ), _round_up(Skv, bK)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
    out = flash_prefill_pallas(
        qt, kt, vt, scale=scale, q_offset=q_offset, kv_len=Skv,
        block_q=bQ, block_k=bK, interpret=(impl == "pallas_interpret"))
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
