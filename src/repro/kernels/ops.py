"""Public kernel API with implementation dispatch.

Every op takes ``impl``:
  "jnp"               — pure-jnp oracle (CPU fast path; what the
                        distributed dry-run lowers so cost_analysis
                        sees real FLOPs/bytes),
  "pallas_interpret"  — Pallas kernel, interpret mode (CPU-validated),
  "pallas"            — Pallas kernel compiled for TPU (the target).

DESIGN — the index-table contract
=================================
Decode attention consumes the cache **in place**, in its page-major
storage layout ``[B, KV, S, P, hd]``.  Page selection is an i32 index
table ``sel_idx [B, nSel]`` (``None`` = identity: attend every slot):

  * entries are duplicate-free page slots; order is irrelevant
    (softmax runs over the union of their tokens);
  * raggedness is expressed per page through ``page_len`` — live
    tokens are a prefix of each page, so one i32 per page replaces a
    per-token mask;
  * the Pallas path hands the table to the kernel via scalar prefetch
    and the kernel's BlockSpec ``index_map`` resolves each page
    directly in HBM — selection costs O(nSel) i32, not O(nSel*P*hd)
    gathered KV bytes, and the identity path costs nothing at all;
  * the jnp oracle gathers the selected pages (a copy is inherent to
    jnp) but the copy is O(nSel), and the identity path uses the cache
    arrays directly with no copy.

The raw Pallas entry points require ``interpret`` explicitly; this
module is the only place that maps ``impl`` to an execution mode, so a
direct kernel call can never silently run interpreted.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

IMPLS = ("jnp", "pallas", "pallas_interpret")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Paged decode attention
# ---------------------------------------------------------------------------
def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_len: jnp.ndarray,
                           sel_idx: Optional[jnp.ndarray], scale: float,
                           impl: str = "jnp"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q [B,H,hd]; k/v_pages [B,KV,S,P,hd] (page-major cache storage);
    page_len [B,S] i32; sel_idx [B,nSel] i32 page table or None for the
    identity table.

    Returns (ctx [B,H,hd], page_probs [B,nSel] — true probability mass
    per *selected* page summed over heads; slot space [B,S] when
    sel_idx is None).
    """
    if impl == "jnp":
        return ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                              page_len, sel_idx, scale)
    from repro.kernels.paged_attention import paged_decode_attention_pallas

    B, H, hd = q.shape
    KV, S = k_pages.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    if sel_idx is None:
        sel_idx = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        sel_len = page_len.astype(jnp.int32)
    else:
        sel_idx = sel_idx.astype(jnp.int32)
        sel_len = jnp.take_along_axis(page_len, sel_idx, axis=1) \
            .astype(jnp.int32)
    ctx, page_probs = paged_decode_attention_pallas(
        sel_idx, sel_len, qg, k_pages, v_pages, scale=scale,
        interpret=(impl == "pallas_interpret"))
    return ctx.reshape(B, H, hd), page_probs


def paged_decode_attention_cost(B: int, KV: int, G: int, hd: int, P: int,
                                n_sel: int, kv_itemsize: int = 4) -> dict:
    """Exact per-call HBM traffic / FLOPs of the index-mapped kernel.

    Deterministic from the grid x block specs: each of the B*KV*n_sel
    grid steps DMAs one K page and one V page of [P, hd]; q and ctx are
    resident per (b, kv); the page-prob output is n_sel f32 per batch
    row.  This is the number the benchmarks report as "attention bytes
    accessed" — it is O(n_sel), independent of the slot count S — and
    the single source of the kernel's own ``pl.CostEstimate``.
    """
    kv_bytes = 2 * B * KV * n_sel * P * hd * kv_itemsize
    qo_bytes = 2 * B * KV * G * hd * kv_itemsize
    probs_bytes = B * n_sel * 4
    table_bytes = 2 * B * n_sel * 4
    return {
        "flops": 4 * B * KV * G * n_sel * P * hd,
        "bytes_accessed": kv_bytes + qo_bytes + probs_bytes + table_bytes,
    }


# ---------------------------------------------------------------------------
# Representative page scoring
# ---------------------------------------------------------------------------
def page_score(q: jnp.ndarray, rep_min: jnp.ndarray, rep_max: jnp.ndarray,
               page_mask: jnp.ndarray, scale: float, impl: str = "jnp",
               block_pages: int = 256) -> jnp.ndarray:
    """q [B,H,hd]; rep_min/max [B,KV,S,hd] (page-major); page_mask
    [B,S] bool.

    Returns scores [B,S] f32 (-inf at invalid pages).
    """
    if impl == "jnp":
        return ref.page_score_ref(q, rep_min, rep_max, page_mask, scale)
    from repro.kernels.page_score import page_score_pallas

    B, H, hd = q.shape
    KV, S = rep_min.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bS = min(block_pages, S)
    Sp = _round_up(S, bS)
    rmin, rmax, mask = rep_min, rep_max, page_mask.astype(jnp.float32)
    if Sp != S:
        pad = Sp - S
        rmin = jnp.pad(rmin, ((0, 0), (0, 0), (0, pad), (0, 0)))
        rmax = jnp.pad(rmax, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    out = page_score_pallas(qg, rmin, rmax, mask, scale=scale,
                            block_pages=bS,
                            interpret=(impl == "pallas_interpret"))
    return out[:, :S]


# ---------------------------------------------------------------------------
# Flash prefill
# ---------------------------------------------------------------------------
def flash_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  scale: float, q_offset=0, kv_len=None,
                  impl: str = "jnp",
                  block_q: int = 256, block_k: int = 256) -> jnp.ndarray:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> ctx [B,Sq,H,hd] (causal).

    ``q_offset`` places the queries within the kv sequence: a python
    int for one-shot prefill, or a per-lane [B] i32 array for
    chunk-resume (each serving lane continues at its own progress).
    ``kv_len`` (int, [B] i32, or None = all of Skv) masks keys at
    positions >= it — padding / not-yet-ingested cache tail.

    impl "jnp" switches to the memory-bounded scan flash (custom VJP)
    automatically once the kv length would make the naive [Sq, Skv]
    logits tensor the memory bottleneck; "jnp_naive" forces the oracle.
    Per-lane (array) offsets are a serving-path feature: they route to
    the oracle / Pallas kernel, never to the training scan flash.
    """
    _scalar = (int, np.integer)
    ragged = (q_offset is not None and not isinstance(q_offset, _scalar)) \
        or (kv_len is not None and not isinstance(kv_len, _scalar))
    if impl == "jnp" and k.shape[1] > 1024 and not ragged \
            and kv_len is None:
        impl = "jnp_flash"
    if impl == "jnp_flash":
        if ragged or kv_len is not None:
            # flash_causal has no kv mask and a scalar-only offset; a
            # silent drop of either argument would attend dead keys
            raise ValueError(
                "impl='jnp_flash' supports neither kv_len nor per-lane "
                "offsets; use the oracle ('jnp') or the Pallas kernel")
        from repro.kernels.flash_scan import flash_causal
        return flash_causal(q, k, v, scale, q_offset, block_k)
    if impl in ("jnp", "jnp_naive"):
        return ref.flash_prefill_ref(q, k, v, scale, q_offset, kv_len)
    from repro.kernels.flash_prefill import flash_prefill_pallas

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)                   # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bQ, bK = min(block_q, Sq), min(block_k, Skv)
    Sqp, Skvp = _round_up(Sq, bQ), _round_up(Skv, bK)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
    # per-lane chunk-resume table: [2, B] i32, scalar-prefetched.
    off = jnp.broadcast_to(jnp.asarray(
        0 if q_offset is None else q_offset, jnp.int32).reshape(-1), (B,))
    lim = jnp.broadcast_to(jnp.asarray(
        Skv if kv_len is None else kv_len, jnp.int32).reshape(-1), (B,))
    out = flash_prefill_pallas(
        jnp.stack([off, lim]), qt, kt, vt, scale=scale,
        block_q=bQ, block_k=bK, interpret=(impl == "pallas_interpret"))
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
