"""Public kernel API with implementation dispatch.

Every op takes ``impl``:
  "jnp"               — pure-jnp oracle (CPU fast path; what the
                        distributed dry-run lowers so cost_analysis
                        sees real FLOPs/bytes),
  "pallas_interpret"  — Pallas kernel, interpret mode (CPU-validated),
  "pallas"            — Pallas kernel compiled for TPU (the target).

DESIGN — the index-table contract
=================================
Both serving attention stages consume the cache **in place**, in its
page-major storage layout ``[B, KV, S, P, hd]``: decode streams the
policy-selected pages, and chunked prefill (``paged_flash_prefill``)
streams the contiguous prefill region page-blocked under the per-lane
chunk-resume table — neither ever materializes a token-major copy.
For decode, page selection is an i32 index table ``sel_idx [B, nSel]``
(``None`` = identity: attend every slot):

  * entries are duplicate-free page slots; order is irrelevant
    (softmax runs over the union of their tokens);
  * raggedness is expressed per page through ``page_len`` — live
    tokens are a prefix of each page, so one i32 per page replaces a
    per-token mask;
  * the Pallas path hands the table to the kernel via scalar prefetch
    and the kernel's BlockSpec ``index_map`` resolves each page
    directly in HBM — selection costs O(nSel) i32, not O(nSel*P*hd)
    gathered KV bytes, and the identity path costs nothing at all;
  * the jnp oracle gathers the selected pages (a copy is inherent to
    jnp) but the copy is O(nSel), and the identity path uses the cache
    arrays directly with no copy.

The raw Pallas entry points require ``interpret`` explicitly; this
module is the only place that maps ``impl`` to an execution mode, so a
direct kernel call can never silently run interpreted.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

IMPLS = ("jnp", "pallas", "pallas_interpret")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Paged decode attention
# ---------------------------------------------------------------------------
def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_len: jnp.ndarray,
                           sel_idx: Optional[jnp.ndarray], scale: float,
                           impl: str = "jnp"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q [B,H,hd]; k/v_pages [B,KV,S,P,hd] (page-major cache storage);
    page_len [B,S] i32; sel_idx [B,nSel] i32 page table or None for the
    identity table.

    Returns (ctx [B,H,hd], page_probs [B,nSel] — true probability mass
    per *selected* page summed over heads; slot space [B,S] when
    sel_idx is None).
    """
    if impl == "jnp":
        return ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                              page_len, sel_idx, scale)
    from repro.kernels.paged_attention import paged_decode_attention_pallas

    B, H, hd = q.shape
    KV, S = k_pages.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    if sel_idx is None:
        sel_idx = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        sel_len = page_len.astype(jnp.int32)
    else:
        sel_idx = sel_idx.astype(jnp.int32)
        sel_len = jnp.take_along_axis(page_len, sel_idx, axis=1) \
            .astype(jnp.int32)
    ctx, page_probs = paged_decode_attention_pallas(
        sel_idx, sel_len, qg, k_pages, v_pages, scale=scale,
        interpret=(impl == "pallas_interpret"))
    return ctx.reshape(B, H, hd), page_probs


def paged_decode_attention_cost(B: int, KV: int, G: int, hd: int, P: int,
                                n_sel: int, kv_itemsize: int = 4) -> dict:
    """Exact per-call HBM traffic / FLOPs of the index-mapped kernel.

    Deterministic from the grid x block specs: each of the B*KV*n_sel
    grid steps DMAs one K page and one V page of [P, hd]; q and ctx are
    resident per (b, kv); the page-prob output is n_sel f32 per batch
    row.  This is the number the benchmarks report as "attention bytes
    accessed" — it is O(n_sel), independent of the slot count S — and
    the single source of the kernel's own ``pl.CostEstimate``.
    """
    kv_bytes = 2 * B * KV * n_sel * P * hd * kv_itemsize
    qo_bytes = 2 * B * KV * G * hd * kv_itemsize
    probs_bytes = B * n_sel * 4
    table_bytes = 2 * B * n_sel * 4
    return {
        "flops": 4 * B * KV * G * n_sel * P * hd,
        "bytes_accessed": kv_bytes + qo_bytes + probs_bytes + table_bytes,
    }


# ---------------------------------------------------------------------------
# Representative page scoring
# ---------------------------------------------------------------------------
def page_score(q: jnp.ndarray, rep_min: jnp.ndarray, rep_max: jnp.ndarray,
               page_mask: jnp.ndarray, scale: float, impl: str = "jnp",
               block_pages: int = 256) -> jnp.ndarray:
    """q [B,H,hd]; rep_min/max [B,KV,S,hd] (page-major); page_mask
    [B,S] bool.

    Returns scores [B,S] f32 (-inf at invalid pages).
    """
    if impl == "jnp":
        return ref.page_score_ref(q, rep_min, rep_max, page_mask, scale)
    from repro.kernels.page_score import page_score_pallas

    B, H, hd = q.shape
    KV, S = rep_min.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bS = min(block_pages, S)
    Sp = _round_up(S, bS)
    rmin, rmax, mask = rep_min, rep_max, page_mask.astype(jnp.float32)
    if Sp != S:
        pad = Sp - S
        rmin = jnp.pad(rmin, ((0, 0), (0, 0), (0, pad), (0, 0)))
        rmax = jnp.pad(rmax, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    out = page_score_pallas(qg, rmin, rmax, mask, scale=scale,
                            block_pages=bS,
                            interpret=(impl == "pallas_interpret"))
    return out[:, :S]


# ---------------------------------------------------------------------------
# Flash prefill
# ---------------------------------------------------------------------------
def flash_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  scale: float, q_offset=0, kv_len=None,
                  impl: str = "jnp",
                  block_q: int = 256, block_k: int = 256) -> jnp.ndarray:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> ctx [B,Sq,H,hd] (causal).

    ``q_offset`` places the queries within the kv sequence: a python
    int for one-shot prefill, or a per-lane [B] i32 array for
    chunk-resume (each serving lane continues at its own progress).
    ``kv_len`` (int, [B] i32, or None = all of Skv) masks keys at
    positions >= it — padding / not-yet-ingested cache tail.

    impl "jnp" switches to the memory-bounded scan flash (custom VJP)
    automatically once the kv length would make the naive [Sq, Skv]
    logits tensor the memory bottleneck; "jnp_naive" forces the oracle.
    Per-lane (array) offsets are a serving-path feature: they route to
    the oracle / Pallas kernel, never to the training scan flash.
    """
    _scalar = (int, np.integer)
    ragged = (q_offset is not None and not isinstance(q_offset, _scalar)) \
        or (kv_len is not None and not isinstance(kv_len, _scalar))
    if impl == "jnp" and k.shape[1] > 1024 and not ragged \
            and kv_len is None:
        impl = "jnp_flash"
    if impl == "jnp_flash":
        if ragged or kv_len is not None:
            # flash_causal has no kv mask and a scalar-only offset; a
            # silent drop of either argument would attend dead keys
            raise ValueError(
                "impl='jnp_flash' supports neither kv_len nor per-lane "
                "offsets; use the oracle ('jnp') or the Pallas kernel")
        from repro.kernels.flash_scan import flash_causal
        return flash_causal(q, k, v, scale, q_offset, block_k)
    if impl in ("jnp", "jnp_naive"):
        return ref.flash_prefill_ref(q, k, v, scale, q_offset, kv_len)
    from repro.kernels.flash_prefill import flash_prefill_pallas

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)                   # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bQ, bK = min(block_q, Sq), min(block_k, Skv)
    Sqp, Skvp = _round_up(Sq, bQ), _round_up(Skv, bK)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
    # per-lane chunk-resume table: [2, B] i32, scalar-prefetched.
    off = jnp.broadcast_to(jnp.asarray(
        0 if q_offset is None else q_offset, jnp.int32).reshape(-1), (B,))
    lim = jnp.broadcast_to(jnp.asarray(
        Skv if kv_len is None else kv_len, jnp.int32).reshape(-1), (B,))
    out = flash_prefill_pallas(
        jnp.stack([off, lim]), qt, kt, vt, scale=scale,
        block_q=bQ, block_k=bK, interpret=(impl == "pallas_interpret"))
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Paged flash prefill (zero-copy chunk-resume over the page-major cache)
# ---------------------------------------------------------------------------
def paged_prefill_geometry(Sq: int, ctx_pages: int, page_size: int,
                           block_q: int = 256,
                           block_k: int = 256) -> Tuple[int, int]:
    """(bQ, pages_per_block) the paged prefill kernel runs with.

    The kv block is a whole number of pages: grown by doubling from one
    page toward ``block_k`` tokens, while still dividing ``ctx_pages``
    (with the engine's power-of-two bucketing every value divides
    evenly; a non-power-of-two ``ctx_pages`` just stops doubling
    earlier).  Exposed so the analytic cost model and the benchmarks
    can reproduce the exact grid the kernel will run.
    """
    bQ = min(block_q, Sq)
    ppb = 1
    while (ppb * 2 * page_size <= block_k
           and ctx_pages % (ppb * 2) == 0):
        ppb *= 2
    return bQ, ppb


def paged_flash_prefill(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, scale: float,
                        q_offset, kv_len, *, ctx_pages: int,
                        impl: str = "jnp", block_q: int = 256,
                        block_k: int = 256) -> jnp.ndarray:
    """Chunk-resume causal prefill reading the paged cache **in place**.

    q [B, C, H, hd] (token-major chunk queries, as projected);
    k/v_pages [B, KV, S, P, hd] — the kernel-native page-major cache
    storage.  ``q_offset`` [B] i32 (or int) places each lane's chunk at
    its resume position; ``kv_len`` [B] i32 (or int) is each lane's
    live kv length (q_offset + live chunk tokens; 0 freezes the lane's
    rows entirely — ride-along lanes in a batched dispatch cost zero
    blocks).  ``ctx_pages`` (static) bounds the prefill region: slots
    [0, ctx_pages), positions [0, ctx_pages * P).

    The Pallas path streams pages straight out of HBM through the
    BlockSpec index map — no token-major gather exists anywhere in the
    dispatch.  The jnp oracle gathers the region (inherent to jnp, and
    exactly what the pre-kernel path did — bit-exact by construction),
    but the copy is O(ctx_pages), never O(S).  Returns ctx [B,C,H,hd].
    """
    if impl in ("jnp", "jnp_naive"):
        return ref.paged_flash_prefill_ref(q, k_pages, v_pages, scale,
                                           q_offset, kv_len, ctx_pages)
    from repro.kernels.paged_flash_prefill import paged_flash_prefill_pallas

    B, Sq, H, hd = q.shape
    P = k_pages.shape[3]
    bQ, ppb = paged_prefill_geometry(Sq, ctx_pages, P, block_q, block_k)
    qt = q.transpose(0, 2, 1, 3)                   # [B, H, Sq, hd]
    Sqp = _round_up(Sq, bQ)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    off = jnp.broadcast_to(jnp.asarray(
        0 if q_offset is None else q_offset, jnp.int32).reshape(-1), (B,))
    lim = jnp.broadcast_to(jnp.asarray(
        ctx_pages * P if kv_len is None else kv_len,
        jnp.int32).reshape(-1), (B,))
    out = paged_flash_prefill_pallas(
        jnp.stack([off, lim]), qt, k_pages, v_pages, scale=scale,
        ctx_pages=ctx_pages, block_q=bQ, pages_per_block=ppb,
        interpret=(impl == "pallas_interpret"))
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def flash_prefill_cost(*, H: int, KV: int, hd: int, Sq: int,
                       ctx_tokens: int, q_offset, kv_len,
                       block_q: int = 256, block_kv: int = 256,
                       itemsize: int = 4) -> dict:
    """Exact per-dispatch HBM traffic / FLOPs of a prefill-chunk kernel.

    Deterministic from the grid x block specs and the chunk-resume
    table, for both prefill kernels (they share ``block_is_live`` and
    the (B, H, nQ, nK) grid): per (lane, head, q-block) the kernel DMAs
    exactly the causally-live, non-dead-tail kv blocks, each
    ``block_kv`` tokens of K and V.  FLOPs count live blocks only —
    ``@pl.when`` really skips dead ones.  A (lane, q-block) sweep with
    zero live blocks is charged one kv-block fetch: its clamped index
    map pins every step to block 0, so the pipeline streams it at most
    once per sweep (and revisit-skips may elide even that — the one
    deliberately conservative term in an otherwise exact count).
    ``q_offset``/``kv_len`` are the per-lane chunk-resume entries (ints
    or arrays); ``ctx_tokens`` is the streamed region (``ctx_pages *
    P`` for the paged kernel, Skv for the dense one).

    Returns ``flops``, ``bytes_accessed`` (the kernel's own traffic —
    identical for the paged and the gather-then-dense path), and
    ``gather_bytes``: the *additional* token-major materialization the
    pre-paged path paid per dispatch (read K+V pages + write the
    token-major copy).  ``gather_bytes`` is what going zero-copy saves;
    the benchmarks assert it strictly positive and report
    ``bytes_accessed`` vs ``bytes_accessed + gather_bytes``.
    """
    off = np.broadcast_to(np.asarray(q_offset, np.int64).reshape(-1), (1,)) \
        if np.ndim(q_offset) == 0 else np.asarray(q_offset, np.int64)
    lim = np.broadcast_to(np.asarray(kv_len, np.int64).reshape(-1), (1,)) \
        if np.ndim(kv_len) == 0 else np.asarray(kv_len, np.int64)
    off, lim = np.broadcast_arrays(off.reshape(-1), lim.reshape(-1))
    B = off.shape[0]
    bQ = min(block_q, Sq)
    nQ = -(-Sq // bQ)
    bT = block_kv
    nK = -(-ctx_tokens // bT)
    live_blocks = fetched_blocks = 0
    for o, l in zip(off.tolist(), lim.tolist()):
        for qi in range(nQ):
            last_q = qi * bQ + (bQ - 1) + o
            # blocks with first_k_pos <= last_q AND first_k_pos < l
            n_live = min(nK, -(-min(last_q + 1, l) // bT))
            n_live = max(n_live, 0)
            live_blocks += n_live
            fetched_blocks += max(n_live, 1)   # dead sweep: block 0 only
    kv_bytes = fetched_blocks * H * bT * hd * itemsize * 2
    qo_bytes = 2 * B * H * Sq * hd * itemsize
    table_bytes = 2 * B * 4
    gather_bytes = 4 * B * ctx_tokens * KV * hd * itemsize
    return {
        "flops": 4 * live_blocks * H * bQ * bT * hd,
        "bytes_accessed": kv_bytes + qo_bytes + table_bytes,
        "gather_bytes": gather_bytes,
    }
