"""Pallas TPU kernel: blocked causal flash attention (prefill stage).

The paper keeps the prefill stage dense (sparsity applies to decode
only), so this is a standard online-softmax flash kernel, GQA-aware via
the BlockSpec index map (kv head = query head // G).

Chunk-resume support: the serving engine ingests long prompts in
chunks, several lanes per dispatch, each lane resumed at its own
progress.  The per-lane query offset and live kv length therefore
arrive as a scalar-prefetched ``seq_info [2, B]`` i32 table (row 0 =
q_offset, row 1 = kv_len) living in SMEM — the causal mask and the
ragged-tail mask are computed against the lane's entries, and the
upper-triangle block skip compares against the lane's offset at run
time instead of a compile-time constant.

Grid (B, H, nQ, nK); the kv axis is sequential (accumulation), blocks
entirely in a lane's causal future — or wholly past its live ``kv_len``
(the ragged dead tail of a chunk-resume batch) — are skipped with
@pl.when so no FLOPs are spent on them (``block_is_live`` is the single
predicate, shared with the paged prefill kernel and traceable by
tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def block_is_live(first_k_pos, last_q_pos, kv_len):
    """Run-time block-skip predicate shared by the prefill kernels.

    A kv block is computed iff it starts at or before the q block's last
    position (causal: not wholly in the future) AND before the lane's
    live kv length (ragged tail: pages past ``kv_len`` hold nothing a
    live query may attend).  Works on python ints, numpy scalars and
    traced values alike, so tests can trace a whole grid through it and
    assert exactly which blocks a dispatch computes.
    """
    return (first_k_pos <= last_q_pos) & (first_k_pos < kv_len)


def _kernel(scale: float, bQ: int, bK: int,
            info_ref,                              # [2, B] SMEM (prefetch)
            q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nK = pl.num_programs(3)
    q_offset = info_ref[0, b]
    kv_len = info_ref[1, b]

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # block skip (run-time, per-lane): the whole kv block is in the
    # future of the whole q block (causal) OR wholly past the lane's
    # live kv length (ragged dead tail) — either way zero FLOPs.
    last_q_pos = qi * bQ + (bQ - 1) + q_offset
    first_k_pos = ki * bK

    @pl.when(block_is_live(first_k_pos, last_q_pos, kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # [bQ, hd]
        k = k_ref[0, 0].astype(jnp.float32)        # [bK, hd]
        v = v_ref[0, 0].astype(jnp.float32)

        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [bQ, bK]
        qpos = qi * bQ + jax.lax.broadcasted_iota(jnp.int32, (bQ, bK), 0) \
            + q_offset
        kpos = ki * bK + jax.lax.broadcasted_iota(jnp.int32, (bQ, bK), 1)
        mask = (qpos >= kpos) & (kpos < kv_len)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        l_s[...] = l_s[...] * corr + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nK - 1)
    def _fin():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "block_k",
                                             "interpret"))
def flash_prefill_pallas(seq_info: jnp.ndarray,
                         q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         scale: float,
                         block_q: int = 256, block_k: int = 256, *,
                         interpret: bool) -> jnp.ndarray:
    """q [B,H,Sq,hd]; k/v [B,KV,Skv,hd] (padded to block multiples).

    ``seq_info`` [2, B] i32 (scalar-prefetched): row 0 is each lane's
    query offset within its kv sequence (0 for one-shot prefill, the
    lane's resume position for chunked prefill), row 1 each lane's true
    kv length (<= Skv; padding and not-yet-ingested keys are masked).
    ``interpret`` is mandatory: only ``ops.py`` decides the execution
    mode.  Returns ctx [B, H, Sq, hd].
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bQ, bK = min(block_q, Sq), min(block_k, Skv)
    assert Sq % bQ == 0 and Skv % bK == 0
    assert seq_info.shape == (2, B)
    nQ, nK = Sq // bQ, Skv // bK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, bQ, hd),
                         lambda b, h, qi, ki, info: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bK, hd),
                         lambda b, h, qi, ki, info: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bK, hd),
                         lambda b, h, qi, ki, info: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bQ, hd),
                               lambda b, h, qi, ki, info: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, scale, bQ, bK)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="raas_flash_prefill",
    )(seq_info, q, k, v)
