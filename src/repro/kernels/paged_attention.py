"""Pallas TPU kernel: single-token paged decode attention (RaaS hot loop).

TPU-native adaptation of the paper's sparse decode step (DESIGN.md §2):
instead of a CUDA gather + FlashInfer call, we stream page blocks
HBM->VMEM along a sequential grid axis and accumulate with an online
softmax in f32 VMEM scratch.  The kernel additionally emits the
*true* per-page probability mass (needed by the H2O baseline and the
paper's Fig-6 fidelity metrics) at negligible cost: per-block
unnormalised exp-sums plus the running row max, fixed up by the ops.py
wrapper after the final block.

Layout (pre-arranged by ops.py):
  qg    [B, KV, G, hd]      G = H // KV query heads per kv head
  kt    [B, KV, T, hd]      T = S * P tokens, page-major
  vt    [B, KV, T, hd]
  mask  [B, T]   f32 0/1

Grid (B, KV, nT): first two axes parallel, last sequential (online
softmax accumulation across token blocks).

Block shapes: token block bT (multiple of page_size P; default 512 =
32 pages) x full head dim.  VMEM working set per step:
2*bT*hd*(kv bytes) + G*hd acc + G*bT probs — e.g. bT=512, hd=128, bf16:
~290 KiB, comfortably inside the ~16 MiB VMEM budget, leaving room for
double buffering of the K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(page_size: int, scale: float,
            q_ref, k_ref, v_ref, mask_ref,
            ctx_ref, psum_ref, bmax_ref, ml_ref,
            m_s, l_s, acc_s):
    t = pl.program_id(2)
    nT = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)            # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bT, hd]
    v = v_ref[0, 0].astype(jnp.float32)            # [bT, hd]
    mask = mask_ref[0] > 0.5                       # [bT]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # [G, bT]
    logits = jnp.where(mask[None, :], logits, NEG_INF)

    m_prev = m_s[...]                              # [G]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask[None, :], jnp.exp(logits - m_new[:, None]), 0.0)

    l_s[...] = l_s[...] * corr + p.sum(axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    # per-page unnormalised exp sums under this block's running max
    bT = p.shape[-1]
    psum_ref[0, 0] = p.reshape(p.shape[0], bT // page_size,
                               page_size).sum(axis=-1)        # [G, pages]
    bmax_ref[0, 0, :, 0] = m_new

    @pl.when(t == nT - 1)
    def _fin():
        denom = jnp.maximum(l_s[...], 1e-30)
        ctx_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(ctx_ref.dtype)
        ml_ref[0, 0, :, 0] = m_s[...]
        ml_ref[0, 0, :, 1] = l_s[...]


@functools.partial(jax.jit, static_argnames=("scale", "page_size",
                                             "block_tokens", "interpret"))
def paged_decode_attention_pallas(qg: jnp.ndarray, kt: jnp.ndarray,
                                  vt: jnp.ndarray, mask: jnp.ndarray,
                                  scale: float, page_size: int,
                                  block_tokens: int = 512,
                                  interpret: bool = True):
    """Raw kernel entry.  See ops.paged_decode_attention for the public API.

    Returns (ctx [B,KV,G,hd], psums [B,KV,G,S], bmax [B,KV,G,nT],
    ml [B,KV,G,2]) — psums/bmax/ml are the online-softmax bookkeeping
    the wrapper uses to reconstruct true page probabilities.
    """
    B, KV, G, hd = qg.shape
    T = kt.shape[2]
    bT = min(block_tokens, T)
    assert T % bT == 0 and bT % page_size == 0
    nT = T // bT
    S = T // page_size
    pages_per_block = bT // page_size

    grid = (B, KV, nT)
    kernel = functools.partial(_kernel, page_size, scale)
    out_shape = (
        jax.ShapeDtypeStruct((B, KV, G, hd), qg.dtype),
        jax.ShapeDtypeStruct((B, KV, G, S), jnp.float32),
        jax.ShapeDtypeStruct((B, KV, G, nT), jnp.float32),
        jax.ShapeDtypeStruct((B, KV, G, 2), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, t: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, bT, hd), lambda b, k, t: (b, k, t, 0)),
            pl.BlockSpec((1, 1, bT, hd), lambda b, k, t: (b, k, t, 0)),
            pl.BlockSpec((1, bT), lambda b, k, t: (b, t)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, G, hd), lambda b, k, t: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G, pages_per_block),
                         lambda b, k, t: (b, k, 0, t)),
            pl.BlockSpec((1, 1, G, 1), lambda b, k, t: (b, k, 0, t)),
            pl.BlockSpec((1, 1, G, 2), lambda b, k, t: (b, k, 0, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        out_shape=out_shape,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="raas_paged_decode_attention",
    )(qg, kt, vt, mask)
