"""Pallas TPU kernel: zero-copy index-mapped paged decode attention.

The RaaS hot loop (DESIGN §2), TPU-native: instead of a CUDA gather +
FlashInfer call — or the dense-kernel-with-a-mask this repo used to
ship, which re-copied the whole cache into a token-major layout every
layer every step — the kernel streams **selected pages only**, straight
out of the page-major HBM cache, vLLM-page-table style:

  * ``sel_idx [B, nSel]`` (scalar-prefetched, SMEM) is the per-sequence
    page table for this step: the i32 slots the policy selected.  The
    K/V BlockSpec ``index_map`` reads it to resolve the HBM block for
    grid step ``(b, kv, s)`` — page gathering is pure DMA indexing, no
    KV byte is ever copied outside the ``pallas_call``.
  * ``sel_len [B, nSel]`` masks the live prefix of each page, so ragged
    partial pages need no per-token mask array.
  * Quest hands over its top-k table; dense/RaaS/H2O/streaming pass the
    identity table (``ops.py`` builds it).  Either way HBM traffic is
    O(nSel * P), never O(S * P).

Grid ``(B, KV, nSel)``: batch parallel; kv-head and page axes
sequential (online-softmax accumulation across pages, page-probability
accumulation across kv heads).  Per grid step the kernel DMAs exactly
one K page and one V page ``[P, hd]`` — the whole working set is
2*P*hd*(kv bytes) + G*hd f32 accumulators + G*nSel f32 page sums, a few
tens of KiB against the ~16 MiB VMEM budget, leaving the pipeliner room
to double-buffer the page stream.

The per-page *true* probability mass (H2O's signal, the paper's Fig-6
fidelity metric) is finalized **in-kernel**: per-page unnormalised
exp-sums are kept in VMEM scratch under the running max (rescaled by
the online-softmax correction each step) and normalised + summed over
kv heads into the ``page_probs [B, nSel]`` output on the last page of
each kv-head sweep.  No wrapper fix-up pass, no scatter back to slot
space for selecting policies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ops import paged_decode_attention_cost

NEG_INF = -1e30


def _kernel(scale: float,
            sel_ref, len_ref,                     # scalar-prefetch (SMEM)
            q_ref, k_ref, v_ref,                  # VMEM blocks
            ctx_ref, probs_ref,                   # outputs
            m_s, l_s, acc_s, psum_s):             # VMEM scratch
    b = pl.program_id(0)
    kv = pl.program_id(1)
    s = pl.program_id(2)
    n_sel = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)
        psum_s[...] = jnp.zeros_like(psum_s)

    q = q_ref[0, 0].astype(jnp.float32)            # [G, hd]
    k = k_ref[0, 0, 0].astype(jnp.float32)         # [P, hd]  (one page)
    v = v_ref[0, 0, 0].astype(jnp.float32)         # [P, hd]
    P = k.shape[0]
    n_live = len_ref[b, s]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, P), 1) < n_live  # [1, P]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # [G, P]
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_s[...]                              # [G]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)

    l_s[...] = l_s[...] * corr + p.sum(axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    # per-page unnormalised exp sums, kept consistent with the running
    # max: rescale history by corr, deposit this page's sum at column s.
    G = p.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (G, psum_s.shape[1]), 1)
    psum_s[...] = psum_s[...] * corr[:, None] + jnp.where(
        col == s, p.sum(axis=-1)[:, None], 0.0)

    @pl.when(s == n_sel - 1)
    def _fin():
        denom = jnp.maximum(l_s[...], 1e-30)
        ctx_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(ctx_ref.dtype)
        # true page probabilities for this kv head, summed over its
        # query group; accumulated over kv heads in the revisited block.
        contrib = (psum_s[...] / denom[:, None]).sum(axis=0)   # [nSel]

        @pl.when(kv == 0)
        def _set():
            probs_ref[0] = contrib

        @pl.when(kv > 0)
        def _add():
            probs_ref[0] = probs_ref[0] + contrib


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_pallas(sel_idx: jnp.ndarray, sel_len: jnp.ndarray,
                                  qg: jnp.ndarray, k_pages: jnp.ndarray,
                                  v_pages: jnp.ndarray, *, scale: float,
                                  interpret: bool):
    """Raw kernel entry.  See ops.paged_decode_attention for the public API.

    sel_idx   [B, nSel] i32  page slots to stream (duplicate-free; every
                             entry must be a valid slot index — pad with
                             any live slot and sel_len 0)
    sel_len   [B, nSel] i32  live tokens per selected page (0..P)
    qg        [B, KV, G, hd]
    k_pages   [B, KV, S, P, hd]  page-major cache storage (read in place)
    v_pages   [B, KV, S, P, hd]

    ``interpret`` is mandatory: only ``ops.py`` decides the execution
    mode, so a direct call can never silently fall back to the
    interpreter.

    Returns (ctx [B, KV, G, hd], page_probs [B, nSel] f32) — the probs
    are true post-softmax per-page mass summed over all query heads.
    """
    B, KV, G, hd = qg.shape
    P = k_pages.shape[3]
    n_sel = sel_idx.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_sel),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, k, s, sel, ln: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, hd),
                         lambda b, k, s, sel, ln: (b, k, sel[b, s], 0, 0)),
            pl.BlockSpec((1, 1, 1, P, hd),
                         lambda b, k, s, sel, ln: (b, k, sel[b, s], 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, G, hd), lambda b, k, s, sel, ln: (b, k, 0, 0)),
            pl.BlockSpec((1, n_sel), lambda b, k, s, sel, ln: (b, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, n_sel), jnp.float32),
        ],
    )
    # single source of truth for the kernel's traffic/FLOPs: the same
    # formula the benchmarks report as attention bytes accessed.
    c = paged_decode_attention_cost(
        B=B, KV=KV, G=G, hd=hd, P=P, n_sel=n_sel,
        kv_itemsize=jnp.dtype(k_pages.dtype).itemsize)
    cost = pl.CostEstimate(
        flops=c["flops"],
        bytes_accessed=c["bytes_accessed"],
        transcendentals=B * KV * G * n_sel * P,
    )
    return pl.pallas_call(
        functools.partial(_kernel, scale),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, KV, G, hd), qg.dtype),
            jax.ShapeDtypeStruct((B, n_sel), jnp.float32),
        ),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        cost_estimate=cost,
        interpret=interpret,
        name="raas_paged_decode_attention",
    )(sel_idx, sel_len, qg, k_pages, v_pages)
