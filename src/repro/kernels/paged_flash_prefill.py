"""Pallas TPU kernel: zero-copy paged flash prefill (chunk-resume).

Chunked prefill used to pay an O(ctx) tax per dispatch: the wrapper
transposed + reshaped the prefill region of the page-major cache into a
token-major copy, per layer, every chunk — exactly the per-step KV
traffic the zero-copy decode kernel already eliminated.  This kernel
closes that gap: a blocked online-softmax causal flash kernel whose K/V
BlockSpec index maps resolve *pages of the kernel-native cache*
``[B, KV, S, P, hd]`` directly — a kv block is ``pages_per_block``
consecutive page slots (prefill pages are laid out contiguously from
slot 0, so slot-space IS position-space for the prefill region), and no
token-major gather ever materializes.

Chunk-resume semantics are identical to the dense prefill kernel: the
scalar-prefetched ``seq_info [2, B]`` table (row 0 = per-lane q_offset,
row 1 = live kv_len) drives the per-lane causal mask and the ragged
page-tail mask (positions >= kv_len inside a page are dead — the same
prefix contract every other kernel relies on).

Traffic discipline, mirroring the paged decode kernel:
  * blocks in a lane's causal future or wholly past its ``kv_len`` are
    skipped with ``@pl.when`` (``block_is_live`` — the predicate shared
    with the dense kernel) so dead tail pages cost zero FLOPs;
  * the K/V index map *clamps* the block index to the lane's last live
    block, so consecutive dead grid steps revisit the same block and
    the pipeline skips their DMAs — dead pages cost (almost) zero HBM
    traffic too, not just zero compute;
  * the kernel streams only the first ``ctx_pages`` slots.  That bound
    is a static grid parameter, so the serving engine buckets it to
    powers of two — O(log S) compiled variants per geometry instead of
    one per chunk boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.flash_prefill import NEG_INF, block_is_live


def _kernel(scale: float, bQ: int, bT: int,
            info_ref,                              # [2, B] SMEM (prefetch)
            q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nK = pl.num_programs(3)
    q_offset = info_ref[0, b]
    kv_len = info_ref[1, b]

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    last_q_pos = qi * bQ + (bQ - 1) + q_offset
    first_k_pos = ki * bT

    @pl.when(block_is_live(first_k_pos, last_q_pos, kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [bQ, hd]
        # one or more whole pages: [ppb, P, hd] -> token rows [bT, hd]
        # (slot-space == position-space for the contiguous prefill
        # region, so collapsing pages recovers token order for free)
        hd = q.shape[-1]
        k = k_ref[0, 0].reshape(bT, hd).astype(jnp.float32)
        v = v_ref[0, 0].reshape(bT, hd).astype(jnp.float32)

        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [bQ, bT]
        qpos = qi * bQ + jax.lax.broadcasted_iota(jnp.int32, (bQ, bT), 0) \
            + q_offset
        kpos = ki * bT + jax.lax.broadcasted_iota(jnp.int32, (bQ, bT), 1)
        # causal + ragged page tail: a partial last page's dead suffix
        # (and anything not yet ingested) sits at positions >= kv_len
        mask = (qpos >= kpos) & (kpos < kv_len)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        l_s[...] = l_s[...] * corr + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nK - 1)
    def _fin():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "ctx_pages",
                                             "block_q", "pages_per_block",
                                             "interpret"))
def paged_flash_prefill_pallas(seq_info: jnp.ndarray, q: jnp.ndarray,
                               k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                               *, scale: float, ctx_pages: int,
                               block_q: int, pages_per_block: int,
                               interpret: bool) -> jnp.ndarray:
    """Raw kernel entry.  See ``ops.paged_flash_prefill`` for the API.

    q          [B, H, Sq, hd]     chunk queries (Sq a block_q multiple)
    k_pages    [B, KV, S, P, hd]  page-major cache storage (in place)
    v_pages    [B, KV, S, P, hd]
    seq_info   [2, B] i32         scalar-prefetched chunk-resume table:
                                  row 0 q_offset, row 1 live kv_len

    ``ctx_pages`` (static) bounds the prefill region streamed: the
    first ``ctx_pages`` slots, which the contiguous prefill layout
    makes positions ``[0, ctx_pages * P)``.  ``pages_per_block``
    (static) is the kv block granularity in whole pages and must divide
    ``ctx_pages``.  ``interpret`` is mandatory: only ``ops.py`` decides
    the execution mode.  Returns ctx [B, H, Sq, hd].
    """
    B, H, Sq, hd = q.shape
    KV, S, P = k_pages.shape[1:4]
    G = H // KV
    ppb = pages_per_block
    bT = ppb * P
    bQ = min(block_q, Sq)
    assert Sq % bQ == 0
    assert ctx_pages % ppb == 0 and 0 < ctx_pages <= S
    assert seq_info.shape == (2, B)
    nQ, nK = Sq // bQ, ctx_pages // ppb

    def kv_index(b, h, qi, ki, info):
        # clamp dead blocks (causal future / ragged tail) onto the
        # lane's last live block: consecutive grid steps then revisit
        # the same block and the pipeline skips the DMA entirely.
        last_q_pos = info[0, b] + (qi + 1) * bQ - 1
        live_end = jnp.minimum(info[1, b] - 1, last_q_pos)      # position
        lim = jnp.maximum(live_end // bT, 0)                    # block idx
        return (b, h // G, jnp.minimum(ki, lim), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, bQ, hd),
                         lambda b, h, qi, ki, info: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, ppb, P, hd), kv_index),
            pl.BlockSpec((1, 1, ppb, P, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, bQ, hd),
                               lambda b, h, qi, ki, info: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ, hd), jnp.float32),
        ],
    )
    # advisory cost: the worst case (every block causally live for every
    # lane).  The exact per-dispatch number — a function of the actual
    # chunk-resume table — is ops.flash_prefill_cost, which the serving
    # engine and benchmarks use for the honest bytes accounting.
    itemsize = jnp.dtype(k_pages.dtype).itemsize
    kv_bytes = B * H * nQ * nK * bT * hd * itemsize * 2
    qo_bytes = 2 * B * H * Sq * hd * jnp.dtype(q.dtype).itemsize
    cost = pl.CostEstimate(
        flops=4 * B * H * nQ * nK * bQ * bT * hd,
        bytes_accessed=kv_bytes + qo_bytes,
        transcendentals=B * H * nQ * nK * bQ * bT,
    )
    kernel = functools.partial(_kernel, scale, bQ, bT)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        cost_estimate=cost,
        interpret=interpret,
        name="raas_paged_flash_prefill",
    )(seq_info, q, k_pages, v_pages)
