"""Memory-bounded causal flash attention in pure jnp (lax.scan + custom VJP).

This is the *lowered* attention used for train/prefill at production
sequence lengths: the dry-run compiles this graph, so cost_analysis
sees real attention FLOPs/bytes, while peak memory stays
O(Sq * block) instead of O(Sq * Skv) — in both the forward scan and
the hand-written FlashAttention-style backward (residuals: out + lse
only, per-block recompute).

The Pallas kernel (flash_prefill.py) is the TPU-target implementation
of the same contract; this module is its jnp twin with a backward pass.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blocks(x: jnp.ndarray, block: int, axis: int) -> jnp.ndarray:
    """[..., T, ...] -> [nb, ..., block, ...] (T padded to multiple)."""
    T = x.shape[axis]
    pad = (-T) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    nb = x.shape[axis] // block
    new_shape = x.shape[:axis] + (nb, block) + x.shape[axis + 1:]
    x = x.reshape(new_shape)
    return jnp.moveaxis(x, axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_causal(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 scale: float, q_offset: int = 0,
                 block: int = 256) -> jnp.ndarray:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> ctx [B,Sq,H,hd], causal."""
    out, _ = _fwd_impl(q, k, v, scale, q_offset, block)
    return out


def _fwd_impl(q, k, v, scale, q_offset, block):
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    qg = qg.transpose(0, 2, 3, 1, 4)                     # [B,KV,G,Sq,hd]
    kb = _blocks(k.astype(jnp.float32).transpose(0, 2, 1, 3), block, 2)
    vb = _blocks(v.astype(jnp.float32).transpose(0, 2, 1, 3), block, 2)
    nb = kb.shape[0]
    qpos = (jnp.arange(Sq) + q_offset)[None, None, None, :, None]

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, bi = xs                              # [B,KV,block,hd]
        logits = jnp.einsum("bkgqd,bktd->bkgqt", qg, kblk) * scale
        kpos = bi * block + jnp.arange(block)
        mask = (qpos >= kpos) & (kpos < Skv)[None, None, None, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new[..., None]), 0.0)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqt,bktd->bkgqd",
                                                 p, vblk)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    denom = jnp.maximum(l, 1e-30)
    out = (acc / denom[..., None])
    lse = m + jnp.log(denom)                             # [B,KV,G,Sq]
    out_q = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out_q.astype(q.dtype), lse


def _fwd(q, k, v, scale, q_offset, block):
    out, lse = _fwd_impl(q, k, v, scale, q_offset, block)
    return out, (q, k, v, out, lse)


def _bwd(scale, q_offset, block, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)                        # [B,KV,G,Sq,hd]
    og = out.reshape(B, Sq, KV, G, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)
    dg = dout.reshape(B, Sq, KV, G, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)
    D = (dg * og).sum(-1)                                # [B,KV,G,Sq]

    kb = _blocks(k.astype(jnp.float32).transpose(0, 2, 1, 3), block, 2)
    vb = _blocks(v.astype(jnp.float32).transpose(0, 2, 1, 3), block, 2)
    nb = kb.shape[0]
    qpos = (jnp.arange(Sq) + q_offset)[None, None, None, :, None]

    def body(dq, xs):
        kblk, vblk, bi = xs
        logits = jnp.einsum("bkgqd,bktd->bkgqt", qg, kblk) * scale
        kpos = bi * block + jnp.arange(block)
        mask = (qpos >= kpos) & (kpos < Skv)[None, None, None, None, :]
        p = jnp.where(mask, jnp.exp(logits - lse[..., None]), 0.0)
        dp = jnp.einsum("bkgqd,bktd->bkgqt", dg, vblk)
        ds = p * (dp - D[..., None]) * scale             # [B,KV,G,Sq,t]
        dq = dq + jnp.einsum("bkgqt,bktd->bkgqd", ds, kblk)
        dk_blk = jnp.einsum("bkgqt,bkgqd->bktd", ds, qg)
        dv_blk = jnp.einsum("bkgqt,bkgqd->bktd", p, dg)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros_like(qg)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0,
                                    (kb, vb, jnp.arange(nb)))
    dq_out = dq.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(B, KV, nb * block, hd)
    dv = jnp.moveaxis(dv_b, 0, 2).reshape(B, KV, nb * block, hd)
    dk = dk[:, :, :Skv].transpose(0, 2, 1, 3)
    dv = dv[:, :, :Skv].transpose(0, 2, 1, 3)
    return (dq_out.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_causal.defvjp(_fwd, _bwd)
