"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel in ``ops.py`` must be
allclose to the function of the same name here for every shape/dtype in
the test sweep.  They are also the CPU fast path used by the rest of
the framework (``impl='jnp'``).

Shapes (decode):
  q          [B, H, hd]           one new query token per sequence
  k_pages    [B, S, P, KV, hd]    S slots of P tokens each
  v_pages    [B, S, P, KV, hd]
  token_mask [B, S, P]  bool      which cached token positions are live
  rep_min    [B, S, KV, hd]       channelwise min of keys in the page
  rep_max    [B, S, KV, hd]

GQA: H query heads map onto KV kv-heads in contiguous groups of
G = H // KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def paged_decode_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                               v_pages: jnp.ndarray, token_mask: jnp.ndarray,
                               scale: float):
    """Single-token paged attention.

    Returns ``(ctx [B, H, hd], page_probs [B, S])`` where ``page_probs``
    is the true post-softmax probability mass per page, summed over all
    query heads (consumed by the H2O policy).
    """
    B, H, hd = q.shape
    S, P, KV = k_pages.shape[1:4]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    k = k_pages.astype(jnp.float32)
    v = v_pages.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bspkd->bkgsp", qg, k) * scale
    mask = token_mask[:, None, None, :, :]
    logits = jnp.where(mask, logits, _NEG_INF)
    flat = logits.reshape(B, KV, G, S * P)
    m = jnp.max(flat, axis=-1, keepdims=True)
    e = jnp.exp(flat - m)
    e = jnp.where(flat <= _NEG_INF / 2, 0.0, e)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = (e / jnp.maximum(denom, 1e-30)).reshape(B, KV, G, S, P)
    ctx = jnp.einsum("bkgsp,bspkd->bkgd", probs, v)
    page_probs = probs.sum(axis=(1, 2, 4))  # sum over kv-heads, groups, in-page
    return ctx.reshape(B, H, hd).astype(q.dtype), page_probs


def page_score_ref(q: jnp.ndarray, rep_min: jnp.ndarray, rep_max: jnp.ndarray,
                   page_mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Quest-style representative page scores.

    Per query head h and page s:  u_hs = sum_d max(q_d*min_d, q_d*max_d)
    (an upper bound on any in-page token's logit).  The per-page score
    is the max over all query heads, scaled like a logit.  Invalid pages
    get -inf.  Returns [B, S] f32.
    """
    B, H, hd = q.shape
    S, KV = rep_min.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    # the bound takes the elementwise max *before* the channel sum
    qe = qg[:, :, :, None, :]                                   # [B,KV,G,1,hd]
    rmin = rep_min.astype(jnp.float32).transpose(0, 2, 1, 3)    # [B,KV,S,hd]
    rmax = rep_max.astype(jnp.float32).transpose(0, 2, 1, 3)
    elem = jnp.maximum(qe * rmin[:, :, None], qe * rmax[:, :, None])
    u = elem.sum(-1) * scale                                    # [B,KV,G,S]
    score = u.max(axis=(1, 2))                                  # [B,S]
    return jnp.where(page_mask, score, _NEG_INF)


def flash_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      scale: float, q_offset: int = 0) -> jnp.ndarray:
    """Causal full attention for the prefill stage.

    q [B, Sq, H, hd], k/v [B, Skv, KV, hd] -> [B, Sq, H, hd].
    ``q_offset`` places the query block at absolute position offset
    within the kv sequence (for chunked prefill).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    causal = qpos[:, None] >= kpos[None, :]
    logits = jnp.where(causal[None, None, None], logits, _NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    e = jnp.where(logits <= _NEG_INF / 2, 0.0, e)
    probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return ctx.reshape(B, Sq, H, hd).astype(q.dtype)
