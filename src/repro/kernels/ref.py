"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel in ``ops.py`` must be
allclose to the function of the same name here for every shape/dtype in
the test sweep.  They are also the CPU fast path used by the rest of
the framework (``impl='jnp'``).

Shapes (decode) — kernel-native page-major layout:
  q          [B, H, hd]           one new query token per sequence
  k_pages    [B, KV, S, P, hd]    S slots of P tokens per kv head
  v_pages    [B, KV, S, P, hd]
  page_len   [B, S]  i32          live tokens per page (prefix contract)
  sel_idx    [B, nSel] i32        page slots this step attends, or None
                                  for the identity table (all slots)
  rep_min    [B, KV, S, hd]       channelwise min of keys in the page
  rep_max    [B, KV, S, hd]

The index-table contract: ``sel_idx`` entries are duplicate-free page
slots (order irrelevant — softmax is over the union of their tokens);
pages with ``page_len == 0`` contribute nothing.  The oracle gathers
the selected pages (it is jnp — a copy is unavoidable here, but it is
O(nSel), never O(S)); the Pallas kernel resolves the same indices
in-kernel with zero copies.

GQA: H query heads map onto KV kv-heads in contiguous groups of
G = H // KV.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def paged_decode_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                               v_pages: jnp.ndarray, page_len: jnp.ndarray,
                               sel_idx: Optional[jnp.ndarray],
                               scale: float
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token paged attention over the selected pages.

    Returns ``(ctx [B, H, hd], page_probs [B, nSel])`` where
    ``page_probs`` is the true post-softmax probability mass per
    *selected* page, summed over all query heads (consumed by the H2O
    policy).  With ``sel_idx=None`` the full slot range is attended and
    ``page_probs`` is in slot space ``[B, S]``.
    """
    B, H, hd = q.shape
    KV, S, P = k_pages.shape[1:4]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    if sel_idx is None:
        k = k_pages.astype(jnp.float32)                  # [B,KV,S,P,hd]
        v = v_pages.astype(jnp.float32)
        sel_len = page_len                               # [B,S]
    else:
        barange = jnp.arange(B)[:, None]
        # mixed indexing moves the advanced axes to the front:
        # [B, nSel, KV, P, hd] -> kv-major [B, KV, nSel, P, hd]
        k = k_pages[barange, :, sel_idx].transpose(0, 2, 1, 3, 4) \
            .astype(jnp.float32)
        v = v_pages[barange, :, sel_idx].transpose(0, 2, 1, 3, 4) \
            .astype(jnp.float32)
        sel_len = jnp.take_along_axis(page_len, sel_idx, axis=1)
    n_sel = k.shape[2]
    mask = jnp.arange(P)[None, None] < sel_len[:, :, None]   # [B,nSel,P]

    logits = jnp.einsum("bkgd,bkspd->bkgsp", qg, k) * scale
    logits = jnp.where(mask[:, None, None], logits, _NEG_INF)
    flat = logits.reshape(B, KV, G, n_sel * P)
    m = jnp.max(flat, axis=-1, keepdims=True)
    e = jnp.exp(flat - m)
    e = jnp.where(flat <= _NEG_INF / 2, 0.0, e)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = (e / jnp.maximum(denom, 1e-30)).reshape(B, KV, G, n_sel, P)
    ctx = jnp.einsum("bkgsp,bkspd->bkgd", probs, v)
    page_probs = probs.sum(axis=(1, 2, 4))  # sum over kv-heads, groups, in-page
    return ctx.reshape(B, H, hd).astype(q.dtype), page_probs


def page_score_ref(q: jnp.ndarray, rep_min: jnp.ndarray, rep_max: jnp.ndarray,
                   page_mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Quest-style representative page scores.

    Per query head h and page s:  u_hs = sum_d max(q_d*min_d, q_d*max_d)
    (an upper bound on any in-page token's logit).  The per-page score
    is the max over all query heads, scaled like a logit.  Invalid pages
    get -inf.  rep_min/rep_max are page-major ``[B, KV, S, hd]`` — the
    layout already matches the contraction, no transpose required.
    Returns [B, S] f32.
    """
    B, H, hd = q.shape
    KV, S = rep_min.shape[1:3]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    # the bound takes the elementwise max *before* the channel sum
    qe = qg[:, :, :, None, :]                                   # [B,KV,G,1,hd]
    rmin = rep_min.astype(jnp.float32)[:, :, None]              # [B,KV,1,S,hd]
    rmax = rep_max.astype(jnp.float32)[:, :, None]
    elem = jnp.maximum(qe * rmin, qe * rmax)
    u = elem.sum(-1) * scale                                    # [B,KV,G,S]
    score = u.max(axis=(1, 2))                                  # [B,S]
    return jnp.where(page_mask, score, _NEG_INF)


def flash_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      scale: float, q_offset=0,
                      kv_len=None) -> jnp.ndarray:
    """Causal full attention for the prefill stage.

    q [B, Sq, H, hd], k/v [B, Skv, KV, hd] -> [B, Sq, H, hd].
    ``q_offset`` places the query block at absolute position offset
    within the kv sequence: a python int for uniform one-shot prefill,
    or a per-lane [B] i32 array for chunk-resume (each lane's chunk
    resumes at its own progress).  ``kv_len`` (int or [B] i32, None =
    all of Skv) masks keys at positions >= it — the not-yet-ingested
    tail of a ragged chunked-prefill batch.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    off = jnp.asarray(q_offset, jnp.int32).reshape(-1, 1)          # [B|1, 1]
    qpos = jnp.arange(Sq)[None, :] + off                           # [B|1, Sq]
    kpos = jnp.arange(Skv)
    causal = qpos[:, :, None] >= kpos[None, None, :]               # [B|1,Sq,Skv]
    if kv_len is not None:
        lim = jnp.asarray(kv_len, jnp.int32).reshape(-1, 1, 1)
        causal = causal & (kpos[None, None, :] < lim)
    logits = jnp.where(causal[:, None, None], logits, _NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    e = jnp.where(logits <= _NEG_INF / 2, 0.0, e)
    probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return ctx.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_flash_prefill_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                            v_pages: jnp.ndarray, scale: float,
                            q_offset, kv_len,
                            ctx_pages: int) -> jnp.ndarray:
    """Chunk-resume causal prefill over the paged cache's prefill region.

    q [B, C, H, hd] (token-major chunk queries); k/v_pages
    [B, KV, S, P, hd] page-major cache storage; ``q_offset``/``kv_len``
    as in :func:`flash_prefill_ref`; ``ctx_pages`` bounds the prefill
    region attended (slots [0, ctx_pages), i.e. positions
    [0, ctx_pages * P) — prefill pages are contiguous from slot 0).

    This is the semantic ground truth for the zero-copy paged prefill
    kernel AND the pre-kernel token-major path, verbatim: gather the
    region token-major (a copy is inherent to jnp — O(ctx_pages), never
    O(S)) and run the dense oracle over it.  Bit-exactness against the
    old ``blocks.block_prefill_chunk`` gather is by construction.
    """
    B = q.shape[0]
    KV, _S, P, hd = k_pages.shape[1:]
    kc = k_pages[:, :, :ctx_pages].transpose(0, 2, 3, 1, 4) \
        .reshape(B, ctx_pages * P, KV, hd)
    vc = v_pages[:, :, :ctx_pages].transpose(0, 2, 3, 1, 4) \
        .reshape(B, ctx_pages * P, KV, hd)
    return flash_prefill_ref(q, kc, vc, scale, q_offset, kv_len)
