"""Pallas TPU kernel: Quest-style representative page scoring.

The paper's §3.3 "lightweight step" before the attention kernel: the
new token's query attends to one representative (min/max channelwise
bound) per page, producing a single score per page that drives RaaS
timestamp refresh / Quest top-k selection.

score[s] = max_{kv,g}  sum_d  max(q[kv,g,d]*rep_min[s,kv,d],
                                  q[kv,g,d]*rep_max[s,kv,d]) * scale

Grid (B, nS): page-block axis is parallel (no accumulation across
blocks).  VMEM per step: 2*bS*KV*hd f32 rep blocks + KV*G*hd query —
with bS=256, KV=8, hd=128 that's ~2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(scale: float, q_ref, rmin_ref, rmax_ref, valid_ref, out_ref):
    q = q_ref[0].astype(jnp.float32)               # [KV, G, hd]
    rmin = rmin_ref[0].astype(jnp.float32)         # [bS, KV, hd]
    rmax = rmax_ref[0].astype(jnp.float32)
    valid = valid_ref[0] > 0.5                     # [bS]

    # [KV, G, 1, hd] x [1, 1, bS(via move), hd]
    qe = q[:, :, None, :]                                   # [KV,G,1,hd]
    rmin_t = jnp.transpose(rmin, (1, 0, 2))[:, None]        # [KV,1,bS,hd]
    rmax_t = jnp.transpose(rmax, (1, 0, 2))[:, None]
    elem = jnp.maximum(qe * rmin_t, qe * rmax_t)            # [KV,G,bS,hd]
    u = elem.sum(axis=-1) * scale                           # [KV,G,bS]
    score = u.max(axis=(0, 1))                              # [bS]
    out_ref[0] = jnp.where(valid, score, NEG_INF)


@functools.partial(jax.jit, static_argnames=("scale", "block_pages",
                                             "interpret"))
def page_score_pallas(qg: jnp.ndarray, rep_min: jnp.ndarray,
                      rep_max: jnp.ndarray, valid: jnp.ndarray,
                      scale: float, block_pages: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """qg [B,KV,G,hd]; rep_min/max [B,S,KV,hd]; valid [B,S] f32 0/1.

    Returns scores [B, S] f32 (-inf at invalid pages).
    """
    B, KV, G, hd = qg.shape
    S = rep_min.shape[1]
    bS = min(block_pages, S)
    assert S % bS == 0
    nS = S // bS

    return pl.pallas_call(
        functools.partial(_kernel, scale),
        grid=(B, nS),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, bS, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bS, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bS), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, bS), lambda b, s: (b, s)),
        out_shape=jax.ShapeDtypeStruct((B, S), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="raas_page_score",
    )(qg, rep_min, rep_max, valid)
