"""Pallas TPU kernel: Quest-style representative page scoring.

The paper's §3.3 "lightweight step" before the attention kernel: the
new token's query attends to one representative (min/max channelwise
bound) per page, producing a single score per page that drives RaaS
timestamp refresh / Quest top-k selection.

score[s] = max_{kv,g}  sum_d  max(q[kv,g,d]*rep_min[s,kv,d],
                                  q[kv,g,d]*rep_max[s,kv,d]) * scale

The representatives are stored page-major per kv head
(``[B, KV, S, hd]`` — the cache's kernel-native layout), so the page
block axis is a plain slice of dim 2 and the kernel contains no
transposes at all.

Grid (B, nS): page-block axis is parallel (no accumulation across
blocks).  VMEM per step: 2*KV*bS*hd f32 rep blocks + KV*G*hd query —
with bS=256, KV=8, hd=128 that's ~2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(scale: float, q_ref, rmin_ref, rmax_ref, valid_ref, out_ref):
    q = q_ref[0].astype(jnp.float32)               # [KV, G, hd]
    rmin = rmin_ref[0].astype(jnp.float32)         # [KV, bS, hd]
    rmax = rmax_ref[0].astype(jnp.float32)
    valid = valid_ref[0] > 0.5                     # [bS]

    qe = q[:, :, None, :]                                   # [KV,G,1,hd]
    elem = jnp.maximum(qe * rmin[:, None], qe * rmax[:, None])  # [KV,G,bS,hd]
    u = elem.sum(axis=-1) * scale                           # [KV,G,bS]
    score = u.max(axis=(0, 1))                              # [bS]
    out_ref[0] = jnp.where(valid, score, NEG_INF)


@functools.partial(jax.jit, static_argnames=("scale", "block_pages",
                                             "interpret"))
def page_score_pallas(qg: jnp.ndarray, rep_min: jnp.ndarray,
                      rep_max: jnp.ndarray, valid: jnp.ndarray,
                      scale: float, block_pages: int, *,
                      interpret: bool) -> jnp.ndarray:
    """qg [B,KV,G,hd]; rep_min/max [B,KV,S,hd]; valid [B,S] f32 0/1.

    ``interpret`` is mandatory: only ``ops.py`` decides the execution
    mode.  Returns scores [B, S] f32 (-inf at invalid pages).
    """
    B, KV, G, hd = qg.shape
    S = rep_min.shape[2]
    bS = min(block_pages, S)
    assert S % bS == 0
    nS = S // bS

    return pl.pallas_call(
        functools.partial(_kernel, scale),
        grid=(B, nS),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, KV, bS, hd), lambda b, s: (b, 0, s, 0)),
            pl.BlockSpec((1, KV, bS, hd), lambda b, s: (b, 0, s, 0)),
            pl.BlockSpec((1, bS), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, bS), lambda b, s: (b, s)),
        out_shape=jax.ShapeDtypeStruct((B, S), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="raas_page_score",
    )(qg, rep_min, rep_max, valid)
