"""Mamba2 mixer via SSD (state-space duality), train + decode paths.

Train/prefill uses the chunked SSD algorithm [arXiv:2405.21060]:
intra-chunk quadratic term + inter-chunk recurrence over chunk states.
Decode is the O(1) recurrent step on (conv, ssm) state — this is why
RaaS is inapplicable here (DESIGN.md §Arch-applicability): there is no
KV cache to sparsify, the state is already constant-size.

Projections are kept *unfused* (separate z / x / B / C / dt weights and
per-stream depthwise convs) so each parameter shards cleanly: x/z
streams and heads on the "model" axis, group-shared B/C replicated.
A fused in_proj would interleave differently-sharded segments and force
resharding collectives at every split.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import MambaConfig
from repro.models.layers import dense_init, rmsnorm


class MambaState(NamedTuple):
    conv_x: jnp.ndarray  # [B, d_conv-1, d_inner]
    conv_B: jnp.ndarray  # [B, d_conv-1, N]
    conv_C: jnp.ndarray  # [B, d_conv-1, N]
    ssm: jnp.ndarray     # [B, H, P, N] f32


def init_mamba(key, d_model: int, cfg: MambaConfig, dtype) -> dict:
    d_in = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    N = cfg.d_state
    ks = jax.random.split(key, 10)
    dt_init = jnp.exp(jax.random.uniform(ks[8], (H,), jnp.float32,
                                         jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_z": dense_init(ks[0], (d_model, d_in), dtype),
        "in_x": dense_init(ks[1], (d_model, d_in), dtype),
        "in_B": dense_init(ks[2], (d_model, N), dtype),
        "in_C": dense_init(ks[3], (d_model, N), dtype),
        "in_dt": dense_init(ks[4], (d_model, H), dtype),
        "conv_x_w": dense_init(ks[5], (cfg.d_conv, d_in), dtype, scale=3.0),
        "conv_B_w": dense_init(ks[6], (cfg.d_conv, N), dtype, scale=3.0),
        "conv_C_w": dense_init(ks[7], (cfg.d_conv, N), dtype, scale=3.0),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),  # softplus^-1(dt_init)
        "norm": {"scale": jnp.ones((d_in,), dtype)},
        "out_proj": dense_init(ks[9], (d_in, d_model), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 d_conv: int) -> jnp.ndarray:
    """Depthwise causal conv along time.  x [B, T, C], w [d_conv, C]."""
    T = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + T] * w[i][None, None] for i in range(d_conv))
    return out + b


def _init_state(batch: int, d_model: int, cfg: MambaConfig,
                dtype) -> MambaState:
    d_in = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    W = cfg.d_conv - 1
    return MambaState(
        conv_x=jnp.zeros((batch, W, d_in), dtype),
        conv_B=jnp.zeros((batch, W, cfg.d_state), dtype),
        conv_C=jnp.zeros((batch, W, cfg.d_state), dtype),
        ssm=jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------
def mamba_forward(params: dict, u: jnp.ndarray, cfg: MambaConfig,
                  d_model: int, norm_eps: float = 1e-6,
                  return_state: bool = False):
    """u [B, T, D] -> y [B, T, D] (+ final MambaState if requested)."""
    B, T, D = u.shape
    d_in = cfg.d_inner(d_model)
    N, H, P = cfg.d_state, cfg.n_heads(d_model), cfg.head_dim
    Lc = min(cfg.chunk_size, T)
    pad = (-T) % Lc
    Tp = T + pad

    z = jnp.einsum("btd,de->bte", u, params["in_z"])
    x_raw = jnp.einsum("btd,de->bte", u, params["in_x"])
    B_raw = jnp.einsum("btd,de->bte", u, params["in_B"])
    C_raw = jnp.einsum("btd,de->bte", u, params["in_C"])
    dt = jnp.einsum("btd,de->bte", u, params["in_dt"])

    silu = lambda a: jax.nn.silu(a.astype(jnp.float32))
    xc = silu(_causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"],
                           cfg.d_conv))
    Bm = silu(_causal_conv(B_raw, params["conv_B_w"], params["conv_B_b"],
                           cfg.d_conv))
    Cm = silu(_causal_conv(C_raw, params["conv_C_w"], params["conv_C_b"],
                           cfg.d_conv))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                        # [H] negative

    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = Tp // Lc

    xh = xc.reshape(B, nc, Lc, H, P)
    Bc = Bm.reshape(B, nc, Lc, N)
    Cc = Cm.reshape(B, nc, Lc, N)
    dtc = dt.reshape(B, nc, Lc, H)

    a = dtc * A                                          # [B,nc,Lc,H] <= 0
    cum_a = jnp.cumsum(a, axis=2)                        # within chunk

    # intra-chunk (quadratic in Lc): scores[t,s] = (C_t.B_s) e^{ca_t-ca_s} dt_s
    cb = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)           # [B,nc,Lc,Lc]
    decay = jnp.exp(cum_a[:, :, :, None, :] -
                    cum_a[:, :, None, :, :])             # [B,nc,Lc,Lc,H]
    tri = jnp.tril(jnp.ones((Lc, Lc), jnp.float32))
    scores = (cb[..., None] * decay * dtc[:, :, None, :, :]
              * tri[None, None, :, :, None])             # [B,nc,Lc,Lc,H]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores, xh)

    # chunk states: S_c = sum_s e^{ca_last - ca_s} dt_s B_s (x) x_s
    seg = jnp.exp(cum_a[:, :, -1:, :] - cum_a) * dtc     # [B,nc,Lc,H]
    S = jnp.einsum("bclh,bcln,bclhp->bchpn", seg, Bc, xh)  # [B,nc,H,P,N]

    # inter-chunk recurrence over nc (associative scan)
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])            # [B,nc,H]

    def combine(c1, c2):
        d1, s1 = c1
        d2, s2 = c2
        return d1 * d2, s2 + s1 * d2[..., None, None]

    dH, sH = jax.lax.associative_scan(combine, (chunk_decay, S), axis=1)
    # state entering chunk c = scan result of chunk c-1 (shift right)
    H_in = jnp.pad(sH[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))

    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, H_in,
                         jnp.exp(cum_a))
    y = y_intra + y_inter + params["D_skip"][None, None, None, :, None] * xh
    y = y.reshape(B, Tp, d_in)[:, :T]

    zf = jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], (y * zf).astype(u.dtype), norm_eps)
    out = jnp.einsum("bti,id->btd", y, params["out_proj"])

    if not return_state:
        return out
    # ssm state after the last real token: padded tail has dt=0 ->
    # decay 1, contribution 0, so the scan result is unaffected.
    W = cfg.d_conv - 1
    state = MambaState(
        conv_x=jnp.pad(x_raw, ((0, 0), (W, 0), (0, 0)))[:, T:T + W]
        .astype(u.dtype),
        conv_B=jnp.pad(B_raw, ((0, 0), (W, 0), (0, 0)))[:, T:T + W]
        .astype(u.dtype),
        conv_C=jnp.pad(C_raw, ((0, 0), (W, 0), (0, 0)))[:, T:T + W]
        .astype(u.dtype),
        ssm=sH[:, -1],
    )
    return out, state


# ---------------------------------------------------------------------------
# Single-token decode step
# ---------------------------------------------------------------------------
def mamba_step(params: dict, u: jnp.ndarray, state: MambaState,
               cfg: MambaConfig, d_model: int,
               norm_eps: float = 1e-6) -> Tuple[jnp.ndarray, MambaState]:
    """u [B, D] one token -> (y [B, D], state')."""
    B, D = u.shape
    d_in = cfg.d_inner(d_model)
    N, H, P = cfg.d_state, cfg.n_heads(d_model), cfg.head_dim

    z = jnp.einsum("bd,de->be", u, params["in_z"])
    x_new = jnp.einsum("bd,de->be", u, params["in_x"])
    B_new = jnp.einsum("bd,de->be", u, params["in_B"])
    C_new = jnp.einsum("bd,de->be", u, params["in_C"])
    dt = jnp.einsum("bd,de->be", u, params["in_dt"])

    def step_conv(stream_state, new, w, b):
        win = jnp.concatenate([stream_state, new[:, None]], axis=1)
        out = (win * w[None]).sum(axis=1) + b
        return jax.nn.silu(out.astype(jnp.float32)), win[:, 1:]

    xc, new_cx = step_conv(state.conv_x, x_new, params["conv_x_w"],
                           params["conv_x_b"])
    Bm, new_cB = step_conv(state.conv_B, B_new, params["conv_B_w"],
                           params["conv_B_b"])
    Cm, new_cC = step_conv(state.conv_C, C_new, params["conv_C_w"],
                           params["conv_C_b"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    A = -jnp.exp(params["A_log"])
    xh = xc.reshape(B, H, P)

    decay = jnp.exp(dt * A)                              # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, xh)
    ssm = state.ssm * decay[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm, ssm) \
        + params["D_skip"][None, :, None] * xh           # [B,H,P]
    y = y.reshape(B, d_in)

    zf = jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], (y * zf).astype(u.dtype), norm_eps)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"])
    return out, MambaState(conv_x=new_cx.astype(state.conv_x.dtype),
                           conv_B=new_cB.astype(state.conv_B.dtype),
                           conv_C=new_cC.astype(state.conv_C.dtype),
                           ssm=ssm)
