"""Foundational layers: RMSNorm, RoPE, SwiGLU, GQA projections.

Pure-function style: each layer has ``init_<name>(key, cfg, ...) ->
params-dict`` and ``<name>(params, x, ...) -> y``.  Params are plain
dicts of jnp arrays so the whole model is a pytree that pjit can shard
with NamedSharding rules keyed on path names (see launch/shardings.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    import math
    fan_in = shape[0] if len(shape) <= 2 else math.prod(shape[:-1])
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2], f32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Rotate ``x [..., seq, heads, head_dim]`` by ``positions [..., seq]``.

    Uses the split-halves convention (llama/HF "rotate_half").
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                      # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv   # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]                       # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU)
# ---------------------------------------------------------------------------
def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def ffn(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", act, params["w_down"])


# ---------------------------------------------------------------------------
# Attention projections (GQA, optional per-head q/k RMSNorm a la Qwen3)
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (cfg.d_model, cfg.n_heads, hd), dtype),
        "wk": dense_init(kk, (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wv": dense_init(kv, (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wo": dense_init(ko, (cfg.n_heads, hd, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def qkv_project(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> q [B, S, H, hd], k/v [B, S, KV, hd], RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_output(params: dict, ctx: jnp.ndarray) -> jnp.ndarray:
    """ctx [B, S, H, hd] -> [B, S, D]."""
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
