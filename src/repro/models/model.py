"""LM wrapper: embeddings -> period-scanned block stack -> logits.

The layer stack is organised as ``cfg.period`` (a static tuple of
(mixer, ffn) kinds) repeated ``cfg.n_periods`` times.  Parameters and
serving caches for period position j are stacked over periods, and the
stack is executed with ``jax.lax.scan`` — one compiled block body per
period position regardless of depth (critical for compile time with
36-72-layer models on the 512-device dry-run, and the idiomatic TPU
pattern).

Multi-codebook audio (musicgen): tokens [B, T, C]; codebook embeddings
are summed at the input and C parallel heads produce [B, T, C, V]
logits.  VLM / audio frontends are stubs per the assignment: callers
pass precomputed ``prefix_emb`` [B, n_prefix, D].
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, ModelConfig, RaasConfig
from repro.core import paged_cache as pc
from repro.core.policy_base import SparsityPolicy, get_policy
from repro.models import blocks, layers

# Trace-time switch: fully unroll the layer scan.  Used by the dry-run
# cost model — XLA's HloCostAnalysis counts a while-loop body ONCE
# regardless of trip count, so roofline terms are derived from small
# unrolled variants and extrapolated (launch/dryrun.py), while the
# full-depth scanned program proves sharding/compile.
SCAN_UNROLL = [False]


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True) if SCAN_UNROLL[0] \
        else jax.lax.scan(body, init, xs)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    C = cfg.n_codebooks
    keys = jax.random.split(key, 3 + len(cfg.period))
    params = {
        "embed": layers.dense_init(keys[0], (C, cfg.vocab_size, cfg.d_model),
                                   dtype, scale=1.0),
        "norm_f": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            keys[1], (cfg.d_model, C, cfg.vocab_size), dtype)
    block_stacks = []
    for j, (mixer, ffn_kind) in enumerate(cfg.period):
        jkeys = jax.random.split(keys[3 + j], cfg.n_periods)
        stacked = jax.vmap(
            lambda k: blocks.init_block(k, cfg, mixer, ffn_kind, dtype)
        )(jkeys)
        block_stacks.append(stacked)
    params["blocks"] = tuple(block_stacks)
    return params


def _embed(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
           prefix_emb: Optional[jnp.ndarray]) -> jnp.ndarray:
    """tokens [B, T] or [B, T, C] -> h [B, n_prefix + T, D]."""
    if tokens.ndim == 2:
        tokens = tokens[..., None]
    C = cfg.n_codebooks
    emb = params["embed"]                       # [C, V, D]
    h = jnp.take(emb[0], tokens[..., 0], axis=0)
    for c in range(1, C):
        h = h + jnp.take(emb[c], tokens[..., c], axis=0)
    if prefix_emb is not None:
        h = jnp.concatenate([prefix_emb.astype(h.dtype), h], axis=1)
    return h


def _logits(params: dict, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    """h [..., D] -> logits [..., V] (or [..., C, V] for C > 1)."""
    h = layers.rmsnorm(params["norm_f"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        out = jnp.einsum("...d,cvd->...cv", h, params["embed"])
    else:
        out = jnp.einsum("...d,dcv->...cv", h, params["lm_head"])
    if cfg.n_codebooks == 1:
        out = out[..., 0, :]
    return out


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------
def forward_train(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                  prefix_emb: Optional[jnp.ndarray] = None,
                  impl: str = "jnp", remat: bool = True,
                  capacity_factor: float = 1.25
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, T_tot, (C,) V], aux_loss scalar)."""
    h = _embed(params, cfg, tokens, prefix_emb)
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(carry, xs):
        h, aux = carry
        for j, (mixer, ffn_kind) in enumerate(cfg.period):
            h, a = blocks.block_train(
                jax.tree.map(lambda x: x, xs[j]), cfg, h, positions,
                mixer, ffn_kind, impl=impl, capacity_factor=capacity_factor)
            aux = aux + a
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = _scan(body, (h, jnp.zeros((), jnp.float32)),
                        params["blocks"])
    return _logits(params, cfg, h), aux


def loss_fn(logits: jnp.ndarray, targets: jnp.ndarray,
            mask: jnp.ndarray) -> jnp.ndarray:
    """Mean CE.  logits [B,T,V] or [B,T,C,V]; targets match; mask [B,T]."""
    if logits.ndim == 4 and targets.ndim == 2:
        targets = targets[..., None]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    nll = lse - gold                           # [B,T] or [B,T,C]
    if nll.ndim == 3:
        nll = nll.mean(-1)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving cache
# ---------------------------------------------------------------------------
class ModelCache(NamedTuple):
    per_pos: Tuple[blocks.BlockCache, ...]   # one per period position,
                                             # leaves stacked [n_periods, ...]


def cache_spec(cfg: ModelConfig, raas: RaasConfig, max_seq_len: int,
               prefill_len: int, dtype=jnp.float32) -> pc.CacheSpec:
    n_slots = get_policy(raas.policy).cache_slots(raas, max_seq_len,
                                                  prefill_len)
    return pc.CacheSpec(n_slots=n_slots, page_size=raas.page_size,
                        n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.resolved_head_dim, dtype=dtype)


def init_model_cache(cfg: ModelConfig, raas: RaasConfig, batch: int,
                     max_seq_len: int, prefill_len: int = 0,
                     dtype=jnp.float32) -> ModelCache:
    spec = None
    if cfg.has_attention:
        spec = cache_spec(cfg, raas, max_seq_len, prefill_len, dtype)
    per_pos = []
    for mixer, _ffn in cfg.period:
        one = blocks.init_block_cache(cfg, mixer, spec, batch, dtype)
        stacked = jax.tree.map(
            lambda x: jnp.repeat(x[None], cfg.n_periods, axis=0), one)
        per_pos.append(stacked)
    return ModelCache(per_pos=tuple(per_pos))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            lengths: jnp.ndarray, cache: ModelCache,
            prefix_emb: Optional[jnp.ndarray] = None,
            impl: str = "jnp") -> Tuple[ModelCache, jnp.ndarray]:
    """Returns (cache', last_logits [B, (C,) V]).

    ``lengths`` [B] counts *token* length per sequence (prefix tokens,
    if any, are shared and included automatically).
    """
    h = _embed(params, cfg, tokens, prefix_emb)
    B, T = h.shape[:2]
    n_prefix = 0 if prefix_emb is None else prefix_emb.shape[1]
    tot_lengths = lengths + n_prefix
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(h, xs):
        block_params, block_cache = xs
        new_caches = []
        for j, (mixer, ffn_kind) in enumerate(cfg.period):
            h, new_c, _aux = blocks.block_prefill(
                block_params[j], cfg, h, positions, tot_lengths,
                block_cache[j], mixer, ffn_kind, impl=impl)
            new_caches.append(new_c)
        return h, tuple(new_caches)

    h, new_per_pos = _scan(body, h, (params["blocks"], cache.per_pos))
    last_h = jnp.take_along_axis(
        h, (tot_lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return ModelCache(per_pos=new_per_pos), _logits(params, cfg, last_h)


# ---------------------------------------------------------------------------
# Chunked prefill (resumable long-prompt ingest, several lanes at once)
# ---------------------------------------------------------------------------
def prefill_chunk(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                  chunk_lens: jnp.ndarray, start: jnp.ndarray,
                  cache: ModelCache, *, ctx_pages: int,
                  impl: str = "jnp") -> Tuple[ModelCache, jnp.ndarray]:
    """Ingest up to one chunk of prompt tokens per lane, resumably.

    tokens [B, C] i32; ``chunk_lens`` [B] live tokens per lane this
    chunk (0 = lane untouched — finished / decoding / empty lanes ride
    along in the batched dispatch); ``start`` [B] each lane's resume
    position (tokens already ingested; page-aligned for live lanes —
    the engine keeps chunks at a page multiple).  ``ctx_pages``
    (static) is the prefill region the chunk attends over, read
    **in place** from the page-major cache by the paged flash kernel;
    it must cover every live lane's ``start + chunk_lens`` tokens and
    is otherwise free — the engine buckets it to powers of two so a
    long prompt compiles O(log S) variants of this function, not one
    per chunk boundary.

    Chunked prefill is mathematically identical to one-shot
    :func:`prefill` of the same prompt: chunk c's queries attend all
    previously ingested KV (read straight from the paged cache) plus
    the causal prefix of the chunk itself.

    Returns (cache', last_logits [B, V]) — logits at each lane's final
    live chunk position (``start + chunk_lens - 1``), which is the
    prompt's last token exactly when the lane's prefill completes this
    dispatch; the engine samples the first output token from it.
    """
    if cfg.n_codebooks != 1:
        raise NotImplementedError(
            "prefill_chunk drives single-codebook LMs; multi-codebook "
            "prefill goes through the one-shot prefill path")
    h = _embed(params, cfg, tokens, None)                    # [B, C, D]

    def body(h, xs):
        block_params, block_cache = xs
        new_caches = []
        for j, (mixer, ffn_kind) in enumerate(cfg.period):
            h, new_c, _aux = blocks.block_prefill_chunk(
                block_params[j], cfg, h, start, chunk_lens,
                block_cache[j], mixer, ffn_kind, ctx_pages=ctx_pages,
                impl=impl)
            new_caches.append(new_c)
        return h, tuple(new_caches)

    h, new_per_pos = _scan(body, h, (params["blocks"], cache.per_pos))
    last = jnp.maximum(chunk_lens - 1, 0).astype(jnp.int32)
    last_h = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
    return ModelCache(per_pos=new_per_pos), _logits(params, cfg, last_h)


# ---------------------------------------------------------------------------
# Decode step (the paper's serving loop body)
# ---------------------------------------------------------------------------
class StepStats(NamedTuple):
    """Per-decode-step policy observability, aggregated over the
    attention layers of the stack (all-zero for attention-free models)."""

    evictions: jnp.ndarray       # [B] i32 — pages evicted, summed over layers
    pages_attended: jnp.ndarray  # [B] f32 — mean over layers
    tokens_cached: jnp.ndarray   # [B] i32 — max over layers


def _decode_core(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                 pos: jnp.ndarray, cache: ModelCache, raas: RaasConfig,
                 policy: SparsityPolicy, impl: str = "jnp",
                 write_mask: Optional[jnp.ndarray] = None
                 ) -> Tuple[ModelCache, jnp.ndarray, StepStats]:
    """One decode step through the whole stack, with policy stats.

    ``write_mask`` [B] bool freezes the caches of masked-off lanes
    (finished requests / lanes still mid-prefill) bit-exactly."""
    if token.ndim == 1:
        token = token[:, None]
    B = token.shape[0]
    h = _embed(params, cfg, token[:, None, :], None)[:, 0]   # [B, D]

    def body(h, xs):
        block_params, block_cache = xs
        new_caches, stats_list = [], []
        for j, (mixer, ffn_kind) in enumerate(cfg.period):
            h, new_c, stats = blocks.block_decode(
                block_params[j], cfg, h, pos, block_cache[j], mixer,
                ffn_kind, raas, impl=impl, policy=policy,
                write_mask=write_mask)
            new_caches.append(new_c)
            if stats is not None:
                stats_list.append(stats)
        return h, (tuple(new_caches), tuple(stats_list))

    h, (new_per_pos, layer_stats) = _scan(
        body, h, (params["blocks"], cache.per_pos))
    # each PolicyStats leaf is stacked [n_periods, B] by the layer scan;
    # aggregate over the period axis and across period positions.
    if layer_stats:
        ev = sum(jnp.sum((s.evicted_slot >= 0).astype(jnp.int32), axis=0)
                 for s in layer_stats)
        pa = sum(jnp.mean(s.pages_attended.astype(jnp.float32), axis=0)
                 for s in layer_stats) / len(layer_stats)
        tc = functools.reduce(
            jnp.maximum, [jnp.max(s.tokens_cached, axis=0)
                          for s in layer_stats])
        stats = StepStats(evictions=ev, pages_attended=pa, tokens_cached=tc)
    else:
        zi = jnp.zeros((B,), jnp.int32)
        stats = StepStats(zi, jnp.zeros((B,), jnp.float32), zi)
    return ModelCache(per_pos=new_per_pos), _logits(params, cfg, h), stats


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                pos: jnp.ndarray, cache: ModelCache, raas: RaasConfig,
                impl: str = "jnp",
                policy: Optional[SparsityPolicy] = None
                ) -> Tuple[ModelCache, jnp.ndarray]:
    """token [B] or [B, C]; pos [B] absolute positions.

    Returns (cache', logits [B, (C,) V]).
    """
    if policy is None:
        policy = get_policy(raas.policy)
    cache, logits, _stats = _decode_core(params, cfg, token, pos, cache,
                                         raas, policy, impl=impl)
    return cache, logits


# ---------------------------------------------------------------------------
# Fused multi-step decode (one dispatch per K tokens)
# ---------------------------------------------------------------------------
class ChunkResult(NamedTuple):
    """Device-side result of :func:`decode_chunk`.

    ``tokens``/``emitted`` are per-step: ``tokens[k, b]`` is the greedy
    token produced at step ``k`` and is meaningful where
    ``emitted[k, b]`` (the lane was active at the start of the step).
    The scalar-per-lane fields are the final carry, used by the engine
    to resume the next chunk without recomputing anything on host.
    """

    tokens: jnp.ndarray     # [K, B] i32
    emitted: jnp.ndarray    # [K, B] bool
    ok: jnp.ndarray         # [K, B] bool — the step's logits were all
                            # finite (a free on-device NaN/Inf guard;
                            # the engine quarantines a lane whose
                            # emitted step reads False)
    token: jnp.ndarray      # [B] i32 — feed token for the next chunk
    pos: jnp.ndarray        # [B] i32
    active: jnp.ndarray     # [B] bool
    n_emitted: jnp.ndarray  # [B] i32
    stats: StepStats        # leaves [K, B]


def chunk_result_sharding(lane, step_lane) -> "ChunkResult":
    """Sharding pytree matching :class:`ChunkResult`'s structure.

    ``lane`` is the sharding of a flat per-lane buffer ([B]: lane axis
    0), ``step_lane`` of a per-step-per-lane buffer ([K, B]: lane axis
    1).  The serving engine passes these as the ``out_shardings`` of
    its fused decode dispatch so chunk outputs stay lane-sharded on
    device instead of being re-laid-out by the partitioner.
    """
    return ChunkResult(
        tokens=step_lane, emitted=step_lane, ok=step_lane, token=lane,
        pos=lane, active=lane, n_emitted=lane,
        stats=StepStats(evictions=step_lane, pages_attended=step_lane,
                        tokens_cached=step_lane))


def decode_chunk(params: dict, cfg: ModelConfig, cache: ModelCache,
                 token: jnp.ndarray, pos: jnp.ndarray,
                 active: jnp.ndarray, n_emitted: jnp.ndarray,
                 eos_id: jnp.ndarray, max_new: jnp.ndarray,
                 raas: RaasConfig, *, steps: int, max_seq: int,
                 impl: str = "jnp",
                 policy: Optional[SparsityPolicy] = None
                 ) -> Tuple[ModelCache, ChunkResult]:
    """Run ``steps`` greedy decode steps inside one ``lax.scan``.

    The engine's hot path: one jit dispatch advances every lane by up
    to K tokens, with sampling (greedy argmax), EOS / length stopping
    and per-step stats all on device — the host only syncs at chunk
    boundaries.  Per-lane dynamic state:

      token      [B] i32   feed token (last sampled, or stale if done)
      pos        [B] i32   absolute position of the feed token
      active     [B] bool  lane is generating (False: the lane is
                           *frozen* — its cache, token, pos and outputs
                           are all bit-exactly unchanged, so finished
                           lanes and lanes still mid-prefill ride along
                           in the batched dispatch unharmed)
      n_emitted  [B] i32   tokens emitted so far (incl. the prefill's
                           first sampled token)
      eos_id     [B] i32   stop token, -1 = none
      max_new    [B] i32   per-request new-token budget

    ``steps`` and ``max_seq`` are static.  Token-identical to calling
    :func:`decode_step` ``steps`` times with host-side argmax and
    masking (verified by tests/test_serving_chunked.py).
    """
    if policy is None:
        policy = get_policy(raas.policy)
    if cfg.n_codebooks != 1:
        raise NotImplementedError(
            "decode_chunk drives single-codebook LMs; multi-codebook "
            "decode still goes through decode_step")

    def one(carry, _):
        cache, token, pos, active, n_emitted = carry
        cache, logits, stats = _decode_core(params, cfg, token, pos,
                                            cache, raas, policy, impl=impl,
                                            write_mask=active)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # [B]
        # free NaN/Inf guard: a poisoned lane's logits go non-finite
        # (argmax of all-NaN is garbage); surfacing the mask as a chunk
        # output lets the engine quarantine that lane at the boundary
        # without a single extra host transfer.
        ok = jnp.all(jnp.isfinite(logits), axis=-1)             # [B]
        emitted = active
        inc = emitted.astype(jnp.int32)
        pos = pos + inc
        n_emitted = n_emitted + inc
        hit_eos = (eos_id >= 0) & (nxt == eos_id)
        done = emitted & (hit_eos | (n_emitted >= max_new)
                          | (pos >= max_seq - 1))
        token = jnp.where(emitted, nxt, token)
        return (cache, token, pos, active & ~done, n_emitted), \
            (nxt, emitted, ok, stats)

    init = (cache, token.astype(jnp.int32), pos.astype(jnp.int32),
            active, n_emitted.astype(jnp.int32))
    (cache, token, pos, active, n_emitted), (toks, emitted, oks, stats) = \
        jax.lax.scan(one, init, None, length=steps)
    return cache, ChunkResult(tokens=toks, emitted=emitted, ok=oks,
                              token=token, pos=pos, active=active,
                              n_emitted=n_emitted, stats=stats)
