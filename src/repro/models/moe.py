"""Mixture-of-Experts FFN with top-k routing (OLMoE / Kimi-K2 / Jamba).

Capacity-based dispatch (Switch-style), formulated so compiled FLOPs
equal the *active* expert compute (2*3*N*k*cf*D*F) rather than the
all-experts product — this keeps the dry-run roofline honest for
E=384 (Kimi-K2).

Pipeline per MoE layer:
  1. router logits + top-k (f32),
  2. position-in-expert via a cumsum over the [N, E] assignment
     one-hot (partitions as a prefix-scan under pjit),
  3. scatter tokens into a [E, C, D] dispatch buffer
     (sharding: experts on "model", capacity on "data" — XLA lowers
     the cross-shard scatter to the expert-parallel all-to-all),
  4. batched expert FFN einsum [E,C,D] x [E,D,F],
  5. gather back and combine with renormalised gates.

Tokens beyond an expert's capacity C = ceil(N*k/E * capacity_factor)
are dropped (standard Switch behaviour).  Tests verify equivalence with
a dense all-experts reference when C >= N.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.models.layers import dense_init


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff
    return {
        "router": dense_init(kr, (d_model, E), jnp.float32),
        "w_gate": dense_init(kg, (E, d_model, F), dtype),
        "w_up": dense_init(ku, (E, d_model, F), dtype),
        "w_down": dense_init(kd, (E, F, d_model), dtype),
    }


def _capacity(n_tokens: int, cfg: MoEConfig, capacity_factor: float) -> int:
    c = -(-n_tokens * cfg.top_k * capacity_factor // cfg.n_experts)
    return max(cfg.top_k, min(int(c), n_tokens))


def moe_ffn(params: dict, x: jnp.ndarray, cfg: MoEConfig,
            capacity_factor: float = 1.25,
            router_key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [..., T, D] -> (y [..., T, D], aux_loss scalar f32)."""
    *lead, T, D = x.shape
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(N, cfg, capacity_factor)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["router"])
    if router_key is not None and cfg.router_jitter > 0:
        logits = logits + cfg.router_jitter * jax.random.normal(
            router_key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                 # [N, E]
    gate_vals, idx = jax.lax.top_k(probs, K)                # [N, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- position in expert (priority: token order, then top-k rank) ------
    # assignment one-hot over the flattened (N*K) choices, expert-major
    # cumulative count gives each choice its slot within its expert.
    choice_oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)     # [N, K, E]
    flat_oh = choice_oh.reshape(N * K, E)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh             # excl. prefix
    pos_in_e = (pos * flat_oh).sum(-1).reshape(N, K)        # [N, K]
    keep = pos_in_e < C

    flat_e = idx.reshape(-1)                                # [N*K]
    flat_pos = jnp.minimum(pos_in_e.reshape(-1), C - 1)
    flat_keep = keep.reshape(-1)

    # -- dispatch: scatter the (tiny) token-index map, GATHER the data ----
    # Scattering D-wide rows into the [E, C, D] buffer makes GSPMD
    # materialise + all-reduce the whole buffer per layer (measured
    # 291 GB/layer/device on kimi-k2 — §Perf it1/it4).  Scattering only
    # int32 token ids ([E, C], ~KB-MB) and gathering rows afterwards
    # lowers to an all-gather of the token activations instead.
    xe = xf.astype(params["w_gate"].dtype)
    token_rows = jnp.repeat(jnp.arange(N), K)
    flat_slot = jnp.where(flat_keep, flat_e * C + flat_pos, E * C)
    slot_token = jnp.full((E * C,), N, jnp.int32).at[flat_slot].set(
        token_rows.astype(jnp.int32), mode="drop")
    x_pad = jnp.concatenate([xe, jnp.zeros((1, D), xe.dtype)], axis=0)
    xbuf = x_pad[slot_token].reshape(E, C, D)
    if cfg.dispatch_axes is not None:
        from jax.sharding import PartitionSpec
        xbuf = jax.lax.with_sharding_constraint(
            xbuf, PartitionSpec(*cfg.dispatch_axes))

    # -- expert FFN ---------------------------------------------------------
    gate = jnp.einsum("ecd,edf->ecf", xbuf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xbuf, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
    ybuf = jnp.einsum("ecf,efd->ecd", act, params["w_down"])  # [E, C, D]
    if cfg.dispatch_axes is not None:
        from jax.sharding import PartitionSpec
        ybuf = jax.lax.with_sharding_constraint(
            ybuf, PartitionSpec(*cfg.dispatch_axes))

    # -- gather back + combine ---------------------------------------------
    gathered = ybuf[flat_e, flat_pos]                        # [N*K, D]
    w = (gate_vals.reshape(-1) * flat_keep).astype(jnp.float32)
    y = (gathered.astype(jnp.float32) * w[:, None]).reshape(N, K, D).sum(1)

    # Switch-style load-balance loss
    frac_tokens = (choice_oh.sum(axis=(0, 1)).astype(jnp.float32)
                   / (N * K))
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * mean_prob) * cfg.load_balance_coef
    return y.astype(x.dtype).reshape(*lead, T, D), aux


def moe_ffn_dense_reference(params: dict, x: jnp.ndarray,
                            cfg: MoEConfig) -> jnp.ndarray:
    """All-experts reference (O(E) FLOPs) — test oracle for dispatch."""
    *lead, T, D = x.shape
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros_like(probs).at[
        jnp.arange(N)[:, None], idx].set(gate_vals)          # [N, E]
    xe = xf.astype(params["w_gate"].dtype)
    gate = jnp.einsum("nd,edf->enf", xe, params["w_gate"])
    up = jnp.einsum("nd,edf->enf", xe, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
    ye = jnp.einsum("enf,efd->end", act, params["w_down"])
    y = jnp.einsum("end,ne->nd", ye.astype(jnp.float32), combine)
    return y.astype(x.dtype).reshape(*lead, T, D)
