"""Transformer blocks: mixer (attention | mamba) + FFN (dense | moe | none).

Three execution paths per block:
  * ``block_train``   — full-sequence causal, no cache (training).
  * ``block_prefill`` — full-sequence causal + ingest KV into the paged
    cache / capture mamba state (serving, stage 1).
  * ``block_decode``  — one token, policy-aware sparse attention via
    core.attention.decode_attend (serving, stage 2 — the paper's loop).

Pre-norm residual wiring: h += mixer(norm(h)); h += ffn(norm(h)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, MAMBA, FFN_DENSE, FFN_MOE, ModelConfig, RaasConfig
from repro.core import attention as core_attention
from repro.core import paged_cache as pc
from repro.kernels import ops
from repro.models import layers, mamba2, moe


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, mixer: str, ffn_kind: str,
               dtype) -> dict:
    km, kf = jax.random.split(key)
    p = {"norm_mixer": layers.init_rmsnorm(cfg.d_model, dtype)}
    if mixer == ATTN:
        p["attn"] = layers.init_attn(km, cfg, dtype)
    else:
        p["mamba"] = mamba2.init_mamba(km, cfg.d_model, cfg.mamba, dtype)
    if ffn_kind != "none":
        p["norm_ffn"] = layers.init_rmsnorm(cfg.d_model, dtype)
        if ffn_kind == FFN_DENSE:
            p["ffn"] = layers.init_ffn(kf, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["moe"] = moe.init_moe(kf, cfg.d_model, cfg.moe, dtype)
    return p


# ---------------------------------------------------------------------------
# FFN sub-step (shared by all paths)
# ---------------------------------------------------------------------------
def _ffn_step(params: dict, cfg: ModelConfig, h: jnp.ndarray,
              ffn_kind: str, capacity_factor: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "none":
        return h, aux
    hn = layers.rmsnorm(params["norm_ffn"], h, cfg.norm_eps)
    if ffn_kind == FFN_DENSE:
        out = layers.ffn(params["ffn"], hn)
    else:
        out, aux = moe.moe_ffn(params["moe"], hn, cfg.moe,
                               capacity_factor=capacity_factor)
    return h + out, aux


# ---------------------------------------------------------------------------
# Train path (also the no-cache forward used by tests/benchmarks)
# ---------------------------------------------------------------------------
def block_train(params: dict, cfg: ModelConfig, h: jnp.ndarray,
                positions: jnp.ndarray, mixer: str, ffn_kind: str,
                impl: str = "jnp",
                capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h [B, T, D], positions [B, T] -> (h', aux_loss)."""
    hn = layers.rmsnorm(params["norm_mixer"], h, cfg.norm_eps)
    if mixer == ATTN:
        q, k, v = layers.qkv_project(params["attn"], cfg, hn, positions)
        scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
        ctx = ops.flash_prefill(q, k, v, scale, impl=impl)
        h = h + layers.attn_output(params["attn"], ctx)
    else:
        h = h + mamba2.mamba_forward(params["mamba"], hn, cfg.mamba,
                                     cfg.d_model, cfg.norm_eps)
    return _ffn_step(params, cfg, h, ffn_kind, capacity_factor)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
class BlockCache(NamedTuple):
    """Per-block serving state; exactly one field is meaningful.

    ``attn`` is the page-major :class:`~repro.core.paged_cache.
    PagedCache` (``k_pages [B, KV, S, P, hd]``) — the kernel-native
    layout that ``core.attention.decode_attend`` consumes in place.
    Prefill ingest performs the only layout transpose; every decode
    step reads/writes single pages of it.
    """

    attn: Optional[pc.PagedCache]
    mamba: Optional[mamba2.MambaState]


def init_block_cache(cfg: ModelConfig, mixer: str, spec: pc.CacheSpec,
                     batch: int, dtype) -> BlockCache:
    if mixer == ATTN:
        return BlockCache(attn=pc.init_cache(spec, batch), mamba=None)
    return BlockCache(attn=None,
                      mamba=mamba2._init_state(batch, cfg.d_model,
                                               cfg.mamba, dtype))


def block_prefill(params: dict, cfg: ModelConfig, h: jnp.ndarray,
                  positions: jnp.ndarray, lengths: jnp.ndarray,
                  cache: BlockCache, mixer: str, ffn_kind: str,
                  impl: str = "jnp",
                  capacity_factor: float = 2.0
                  ) -> Tuple[jnp.ndarray, BlockCache, jnp.ndarray]:
    """Full-sequence forward + state capture.  Returns (h', cache', aux)."""
    hn = layers.rmsnorm(params["norm_mixer"], h, cfg.norm_eps)
    if mixer == ATTN:
        q, k, v = layers.qkv_project(params["attn"], cfg, hn, positions)
        scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
        ctx = ops.flash_prefill(q, k, v, scale, impl=impl)
        h = h + layers.attn_output(params["attn"], ctx)
        cache = cache._replace(
            attn=pc.ingest_prefill(cache.attn, k, v, lengths))
    else:
        out, mstate = mamba2.mamba_forward(
            params["mamba"], hn, cfg.mamba, cfg.d_model, cfg.norm_eps,
            return_state=True)
        h = h + out
        cache = cache._replace(mamba=mstate)
    h, aux = _ffn_step(params, cfg, h, ffn_kind, capacity_factor)
    return h, cache, aux


def block_prefill_chunk(params: dict, cfg: ModelConfig, h: jnp.ndarray,
                        start: jnp.ndarray, chunk_lens: jnp.ndarray,
                        cache: BlockCache, mixer: str, ffn_kind: str,
                        ctx_pages: int, impl: str = "jnp",
                        capacity_factor: float = 2.0
                        ) -> Tuple[jnp.ndarray, BlockCache, jnp.ndarray]:
    """One *chunk* of prefill, resumable per lane.

    h [B, C, D] is the chunk's hidden states; ``start`` [B] i32 is each
    lane's resume position (tokens already ingested), ``chunk_lens``
    [B] i32 the live tokens of this chunk (0 = lane rides along
    untouched).  ``ctx_pages`` (static) bounds the prefill region of
    the paged cache the chunk attends to: the chunk's keys are ingested
    first, then attention reads the first ``ctx_pages`` slots of the
    page-major cache **in place** (``ops.paged_flash_prefill``: the
    Pallas kernel resolves pages through its BlockSpec index map — no
    token-major gather; the jnp oracle gathers O(ctx_pages)) — prefill
    pages are laid out contiguously from slot 0, so that region IS
    positions [0, ctx_pages * P) and the per-lane causal mask
    (q_offset = start) makes the chunk attend to exactly its own past.
    The serving engine buckets ``ctx_pages`` to powers of two, so long-
    prompt ingest compiles O(log S) variants of this body, not one per
    chunk boundary.  Returns (h', cache', aux).
    """
    hn = layers.rmsnorm(params["norm_mixer"], h, cfg.norm_eps)
    if mixer != ATTN:
        raise NotImplementedError(
            "chunked prefill requires attention mixers; mamba chunk-"
            "resume state is not carried yet — serve SSM/hybrid archs "
            "through the one-shot prefill path")
    B, C = h.shape[:2]
    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    q, k, v = layers.qkv_project(params["attn"], cfg, hn, positions)
    new_pc = pc.ingest_prefill_chunk(cache.attn, k, v, chunk_lens)
    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    # ride-along lanes (chunk_lens == 0) get kv_len 0: every kv block
    # of theirs is dead, so the kernel skips them outright instead of
    # attending a rider's stale context for rows nobody reads.
    kv_len = jnp.where(chunk_lens > 0, start + chunk_lens, 0)
    ctx = ops.paged_flash_prefill(q, new_pc.k_pages, new_pc.v_pages,
                                  scale, start, kv_len,
                                  ctx_pages=ctx_pages, impl=impl)
    h = h + layers.attn_output(params["attn"], ctx)
    cache = cache._replace(attn=new_pc)
    h, aux = _ffn_step(params, cfg, h, ffn_kind, capacity_factor)
    return h, cache, aux


def block_decode(params: dict, cfg: ModelConfig, h: jnp.ndarray,
                 pos: jnp.ndarray, cache: BlockCache, mixer: str,
                 ffn_kind: str, raas: RaasConfig, impl: str = "jnp",
                 capacity_factor: float = 4.0,
                 policy=None, write_mask=None
                 ) -> Tuple[jnp.ndarray, BlockCache, Optional[object]]:
    """One-token step.  h [B, D], pos [B] -> (h', cache', stats).

    ``policy`` is the resolved :class:`SparsityPolicy` object (defaults
    to the registered policy for ``raas.policy``).  ``write_mask`` [B]
    bool freezes the caches of lanes where it is False (finished / mid-
    prefill lanes riding along in a batched dispatch).  ``stats`` is
    the attention layer's :class:`PolicyStats`, or ``None`` for
    attention-free mixers.
    """
    stats = None
    hn = layers.rmsnorm(params["norm_mixer"], h, cfg.norm_eps)
    if mixer == ATTN:
        q, k, v = layers.qkv_project(
            params["attn"], cfg, hn[:, None], pos[:, None])
        new_cache, ctx, stats = core_attention.decode_attend(
            cache.attn, q[:, 0], k[:, 0], v[:, 0], raas, policy=policy,
            write_mask=write_mask, impl=impl)
        h = h + layers.attn_output(params["attn"], ctx[:, None])[:, 0]
        cache = cache._replace(attn=new_cache)
    else:
        out, mstate = mamba2.mamba_step(params["mamba"], hn, cache.mamba,
                                        cfg.mamba, cfg.d_model, cfg.norm_eps)
        h = h + out
        if write_mask is not None:
            # frozen lanes keep their SSM state bit-exactly
            mstate = jax.tree.map(
                lambda new, old: jnp.where(
                    write_mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                    new, old),
                mstate, cache.mamba)
        cache = cache._replace(mamba=mstate)
    h, _aux = _ffn_step(params, cfg, h[:, None], ffn_kind,
                        capacity_factor)
    return h[:, 0], cache, stats
