"""Msgpack pytree checkpointing with host-gather for sharded arrays.

Layout: one ``<step>.msgpack`` per save; arrays are stored as
``{dtype, shape, raw bytes}``; the pytree structure is recovered from
jax.tree flatten-with-path keys so restore works without the original
object graph.  Sharded arrays are gathered to host before writing and
re-sharded on restore via ``jax.device_put(x, sharding)`` when a
sharding tree is provided.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def _encode(x: np.ndarray) -> dict:
    x = np.asarray(x)
    return {"dtype": x.dtype.str, "shape": list(x.shape),
            "data": x.tobytes()}


def _decode(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]).copy()


def save(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for p, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        payload[_key_str(p)] = _encode(arr)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like: Any, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), shd in zip(flat, shard_leaves):
        key = _key_str(p)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _decode(payload[key]).astype(leaf.dtype)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        x = jnp.asarray(arr)
        if shd is not None:
            x = jax.device_put(x, shd)
        out.append(x)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f.split(".")[0]) for f in os.listdir(ckpt_dir)
             if f.endswith(".msgpack") and f.split(".")[0].isdigit()]
    return max(steps) if steps else None
