"""AdamW + cosine LR schedule + global-norm clipping, from scratch.

No optax in this environment; this is the standard decoupled-weight-
decay Adam (Loshchilov & Hutter) with f32 moments regardless of param
dtype (mixed-precision training: bf16 params, f32 optimizer state).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # i32 scalar
    mu: Any                # pytree like params, f32
    nu: Any                # pytree like params, f32


def init(params, moments_dtype=jnp.float32) -> AdamWState:
    """moments_dtype=bf16 halves optimizer memory (large-model option;
    slight quality cost, standard at the >=100B scale)."""
    z = lambda p: jnp.zeros(p.shape, moments_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def cosine_schedule(step: jnp.ndarray, base_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = (step + 1) / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return base_lr * jnp.where(step < warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def update(params, grads, state: AdamWState, lr: jnp.ndarray,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mdt = mu.dtype
        mu = (b1 * mu.astype(jnp.float32) + (1 - b1) * g).astype(mdt)
        nu = (b2 * nu.astype(jnp.float32)
              + (1 - b2) * jnp.square(g)).astype(mdt)
        mhat = mu.astype(jnp.float32) / bc1
        vhat = nu.astype(jnp.float32) / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)
