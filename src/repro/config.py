"""Config system for the RaaS reproduction framework.

Everything in the framework hangs off three frozen dataclasses:

* :class:`ModelConfig`   — architecture definition (one per assigned arch).
* :class:`RaasConfig`    — the paper's KV-sparsity policy knobs.
* :class:`RunConfig`     — training / serving / dry-run run parameters.

Configs are plain frozen dataclasses (hashable, usable as jit static
args).  ``src/repro/configs/<arch>.py`` modules each expose ``CONFIG``;
:func:`get_config` resolves an ``--arch`` id to its ModelConfig.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-kind vocabulary used by the hybrid stacking machinery.
# ---------------------------------------------------------------------------
ATTN = "attn"
MAMBA = "mamba"

FFN_DENSE = "dense"
FFN_MOE = "moe"


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden width
    router_jitter: float = 0.0     # train-time router noise
    load_balance_coef: float = 0.01
    capacity_factor: float = 0.0   # 0.0 = dropless dense-dispatch
    # optional sharding constraint (axis names) for the [E, C, D]
    # dispatch buffer — the expert-parallel perf lever (§Perf): without
    # it GSPMD tends to replicate the buffer and all-reduce the
    # scatter; with ("model", "data", None) the scatter lowers to the
    # expert all-to-all.  None = let the partitioner decide (baseline).
    dispatch_axes: Optional[Tuple[Optional[str], ...]] = None


@dataclass(frozen=True)
class MambaConfig:
    """Mamba2 / SSD mixer configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256          # SSD chunk length for the parallel scan

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition.

    ``period`` describes one repeating block of layers as a tuple of
    (mixer_kind, ffn_kind) pairs; the full stack is ``period`` repeated
    ``n_periods`` times, ``n_layers == n_periods * len(period)``.
    Uniform architectures use a length-1 period.
    """

    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int                     # dense-FFN hidden width (0 if all-MoE/ssm)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # hybrid stacking ------------------------------------------------------
    period: Tuple[Tuple[str, str], ...] = ((ATTN, FFN_DENSE),)
    # sub-configs ----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # modality frontends (stubs per the assignment carve-out) --------------
    frontend: Optional[str] = None   # "siglip_stub" | "encodec_stub"
    n_prefix_tokens: int = 0         # precomputed patch/frame embeddings
    n_codebooks: int = 1             # musicgen-style multi-codebook audio
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    def __post_init__(self) -> None:
        if self.n_layers % len(self.period) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}"
            )
        for mixer, ffn in self.period:
            if mixer not in (ATTN, MAMBA):
                raise ValueError(f"unknown mixer kind {mixer!r}")
            if ffn not in (FFN_DENSE, FFN_MOE, "none"):
                raise ValueError(f"unknown ffn kind {ffn!r}")
            if mixer == MAMBA and self.mamba is None:
                raise ValueError(f"{self.name}: mamba layer without MambaConfig")
            if ffn == FFN_MOE and self.moe is None:
                raise ValueError(f"{self.name}: moe layer without MoEConfig")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def has_attention(self) -> bool:
        return any(m == ATTN for m, _ in self.period)

    @property
    def attn_free(self) -> bool:
        return not self.has_attention

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        p = self.vocab_size * self.d_model * self.n_codebooks
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model * self.n_codebooks
        hd = self.resolved_head_dim
        for mixer, ffn in self.period:
            n = self.n_periods
            if mixer == ATTN:
                qkv = self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads)
                o = self.n_heads * hd * self.d_model
                p += n * (qkv + o)
            else:
                mc = self.mamba
                d_in = mc.d_inner(self.d_model)
                nh = mc.n_heads(self.d_model)
                in_proj = self.d_model * (2 * d_in + 2 * mc.d_state + nh)
                p += n * (in_proj + d_in * self.d_model
                          + mc.d_conv * (d_in + 2 * mc.d_state))
            if ffn == FFN_DENSE:
                p += n * 3 * self.d_model * self.d_ff
            elif ffn == FFN_MOE:
                p += n * (3 * self.d_model * self.moe.d_ff * self.moe.n_experts
                          + self.d_model * self.moe.n_experts)
            p += n * 2 * self.d_model  # norms
        return p

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        moe_layers = sum(1 for _, f in self.period if f == FFN_MOE) * self.n_periods
        all_experts = moe_layers * 3 * self.d_model * self.moe.d_ff * self.moe.n_experts
        active = moe_layers * 3 * self.d_model * self.moe.d_ff * self.moe.top_k
        return full - all_experts + active

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                n_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (per assignment spec)."""
        d_model = min(d_model, 512)
        period = self.period
        n_layers = max(n_layers, len(period))
        n_layers -= n_layers % len(period)
        hd = 64
        n_heads = max(1, d_model // hd) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if self.n_heads else 0
        if n_heads and n_heads % n_kv:
            n_kv = 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(n_experts, self.moe.n_experts),
                top_k=min(self.moe.top_k, min(n_experts, self.moe.n_experts)),
                d_ff=min(self.moe.d_ff, 2 * d_model))
        mamba = None
        if self.mamba is not None:
            mamba = dataclasses.replace(
                self.mamba, d_state=min(self.mamba.d_state, 32),
                head_dim=32, chunk_size=32)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 2 * d_model) if self.d_ff else 0,
            vocab_size=min(vocab, self.vocab_size), head_dim=hd if n_heads else 0,
            moe=moe, mamba=mamba,
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
        )


# ---------------------------------------------------------------------------
# RaaS / sparsity-policy config (the paper's contribution).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RaasConfig:
    """KV-cache sparsity policy configuration (paper §3).

    ``budget_tokens`` is L — the decode-token cache budget.  Prefill
    pages are pinned *in addition* to the budget (paper keeps all
    prefill KV).  ``alpha`` is the post-softmax page-probability
    threshold for timestamp refresh; ``top_r`` is the equivalent
    fraction rule (paper recommends r=50%; "two sides of the same
    coin").  ``use_top_r`` selects which is applied.
    """

    policy: str = "raas"
    budget_tokens: int = 1024
    page_size: int = 16
    alpha: float = 1e-4
    top_r: float = 0.5
    use_top_r: bool = True
    # Quest: number of pages attended per step (top-k pages by score).
    quest_topk_pages: int = 64
    # StreamingLLM: sink tokens (prefill is pinned anyway; extra sinks
    # for the no-prefill corner).
    sink_tokens: int = 4
    # H2O: recent-window tokens always kept.
    h2o_recent: int = 128
    # representative-key scheme: "quest_minmax" (paper-faithful) or
    # "mean" (beyond-paper cheaper variant).
    rep_scheme: str = "quest_minmax"
    # quest_raas hybrid (the paper's own recommendation for long-prefill
    # workloads, recommended in §4.2/Limitations but not implemented
    # there): Quest top-k selection over the prefill pages, RaaS
    # timestamp eviction over decode pages.  Requires the static
    # prefill page count at trace time.
    prefill_pages_hint: int = 0

    def __post_init__(self) -> None:
        # lazy import: the registry lives downstream of this module.
        from repro.core.policy_base import get_policy
        get_policy(self.policy)      # raises ValueError on unknown ids
        if self.budget_tokens % self.page_size:
            raise ValueError("budget_tokens must be a multiple of page_size")

    @property
    def policy_obj(self):
        """The registered :class:`SparsityPolicy` singleton for ``policy``."""
        from repro.core.policy_base import get_policy
        return get_policy(self.policy)

    @property
    def budget_pages(self) -> int:
        return self.budget_tokens // self.page_size


# ---------------------------------------------------------------------------
# Serving deployment config (the engine's static geometry).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ServeConfig:
    """Static geometry of the continuous-batching serving engine.

    ``max_prefill`` is the per-lane *prompt capacity*: how many prompt
    tokens a lane's pinned prefill region can hold (prompts longer than
    this are rejected at admission with a ValueError — never silently
    truncated).  ``prefill_chunk`` is the per-dispatch ingest width:
    long prompts are fed in chunks of this many tokens, interleaved
    with decode chunks, so admitting a long prompt never stalls active
    decode lanes.  It is rounded up to a page multiple by the engine so
    every non-final chunk of a prompt stays page-aligned.

    ``mesh`` is the serving mesh spec ("" = single-device; "data=4" /
    "data=2,model=2" = sharded).  The engine shards its lane axis —
    paged cache, phase/progress tables, token buffers — over the
    "data" axis and params per the decode rule table over "model"
    (:mod:`repro.launch.shardings` engine mode).  ``batch_slots`` must
    be divisible by the data axis size (every device gets a whole
    number of lanes).  The spec is resolved to a live
    ``jax.sharding.Mesh`` by :func:`repro.launch.mesh.make_serving_mesh`
    at engine construction, never at config time.
    """

    batch_slots: int = 4
    max_seq: int = 1024
    max_prefill: int = 128
    prefill_chunk: int = 64
    chunk_steps: int = 8
    mesh: str = ""
    # prefix caching + multi-turn KV sessions (repro.core.page_pool):
    # admission consults a host-side prefix index and mounts / clones
    # already-resident prompt pages instead of re-running prefill, and
    # requests carrying a session_id park their conversation KV for the
    # follow-up turn.  Effective only on attention architectures with
    # chunked prefill (the engine gates it); purely host+metadata —
    # kernels are unchanged either way.
    prefix_caching: bool = True
    # resilience (repro.serving.resilience): transient dispatch
    # failures are retried up to retry_limit attempts with exponential
    # backoff (retry_backoff_s doubling per attempt; 0 = no sleep),
    # then surface as DispatchFailedError and the scheduler drains.
    retry_limit: int = 3
    retry_backoff_s: float = 0.0
    # graceful degradation: after this many consecutive chunk
    # boundaries with the queue starved (no free lane, nothing
    # admitted), the scheduler checkpoints the youngest long decode to
    # host and recycles its lane; 0 disables preemption.  Overridable
    # per serve() call.
    preempt_after: int = 0

    def __post_init__(self) -> None:
        if self.max_prefill > self.max_seq:
            raise ValueError("max_prefill cannot exceed max_seq")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be positive")
        if self.chunk_steps < 1:
            raise ValueError("chunk_steps must be positive")
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be positive")
        if self.retry_limit < 1:
            raise ValueError("retry_limit must be positive")
        if self.retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        if self.preempt_after < 0:
            raise ValueError("preempt_after must be >= 0")
        if self.mesh:
            # lazy import (jax lives downstream); the parse is pure
            # string validation — no device is touched at config time.
            from repro.launch.mesh import parse_mesh_spec
            axes = dict(parse_mesh_spec(self.mesh))
            if self.batch_slots % axes["data"]:
                raise ValueError(
                    f"batch_slots={self.batch_slots} must be divisible "
                    f"by the mesh data axis ({axes['data']}, from "
                    f"mesh={self.mesh!r}) — ragged lane shards would "
                    "force the partitioner to gather")


# ---------------------------------------------------------------------------
# Run config: shapes, meshes, dtypes.
# ---------------------------------------------------------------------------
INPUT_SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    arch: str = "smollm-360m"
    shape: str = "train_4k"
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    remat: bool = True
    seed: int = 0
    # serving / sparsity
    raas: RaasConfig = field(default_factory=RaasConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)

    @property
    def seq_len(self) -> int:
        return INPUT_SHAPES[self.shape][0]

    @property
    def global_batch(self) -> int:
        return INPUT_SHAPES[self.shape][1]

    @property
    def kind(self) -> str:
        return INPUT_SHAPES[self.shape][2]


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "qwen3-8b",
    "paligemma-3b",
    "yi-34b",
    "internlm2-20b",
    "jamba-1.5-large-398b",
    "olmoe-1b-7b",
    "mamba2-780m",
    "musicgen-medium",
    "kimi-k2-1t-a32b",
    "smollm-360m",
    # the paper's own eval model family (Qwen2.5-Math-7B shaped)
    "qwen25-math-7b",
)


def get_config(arch: str) -> ModelConfig:
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    assert isinstance(cfg, ModelConfig)
    return cfg


def list_archs() -> Tuple[str, ...]:
    return ARCH_IDS
