"""Static-analysis suite for the repo's zero-copy serving contracts.

Two layers keep every PR honest about the invariants that make RaaS's
O(L) time *and* O(L) memory real on device:

* :mod:`repro.analysis.lint` — an AST pass over ``src/`` enforcing
  source-level contracts as named, suppressible rules (``pallas_call``
  only in ``kernels/``, explicit ``interpret=`` on raw Pallas entries,
  no host syncs in the serving dispatch loop, no fancy-index gathers on
  the paged cache outside kernels, policy files importing only
  ``policy_base``).
* :mod:`repro.analysis.hlo` — passes over optimized HLO / compiled
  programs (KV-sized-copy detector, host-transfer detector, collective
  accountant, donation auditor, jit-cache-growth guard), shared by the
  tests, the benchmarks and the dry-run tooling.

``python -m repro.analysis.run --strict`` runs both layers over the
repo plus a compiled engine-dispatch matrix and exits non-zero on any
unsuppressed finding — the CI ``static-analysis`` leg.
"""
from repro.analysis.findings import Finding, format_findings  # noqa: F401
