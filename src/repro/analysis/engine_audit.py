"""Audit a live :class:`~repro.serving.engine.Engine`'s jitted
dispatches with the :mod:`repro.analysis.hlo` passes.

The engine's chunked dispatch functions (``reset``, ``prefill_chunk``,
``decode_chunk``, the page pool's ``pool_transition``, and the
preemption path's ``lane_restore``) are lowered
ahead-of-time with ``ShapeDtypeStruct`` stand-ins (no device
allocation beyond what the engine already holds) and compiled; each
optimized program then runs through the KV-copy, host-transfer,
collective and donation passes.  The pool's *clone* dispatch is
deliberately not audited: copying one lane's prefix into another lane
is a cross-shard transfer under lane sharding — an inherent collective
the zero-collective budget would reject, bounded instead by the
engine's ``prefix_clones``/``pool_dispatches`` accounting.
The jit-cache guard is *not* run here — AOT lowering re-traces and
would inflate the engine's trace counters; callers check those against
:func:`repro.analysis.hlo.jit_cache_findings` before auditing.

Used by the CLI (``python -m repro.analysis.run``), the serving
benchmark (donation before/after accounting in ``BENCH_serving.json``)
and tests/test_analysis.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import hlo
from repro.analysis.findings import Finding

DISPATCHES = ("reset", "prefill_chunk", "decode_chunk",
              "pool_transition", "lane_restore")


def _sds(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(jnp.shape(x), x.dtype)


def dispatch_lowerings(eng) -> Dict[str, "jax.stages.Lowered"]:
    """AOT-lower the engine's chunked dispatches with struct stand-ins
    shaped exactly like a real serving call.  Requires the chunked
    prefill path (the one-shot fallback archs splice rows host-side and
    have no reset / prefill_chunk dispatch to audit)."""
    if not eng.chunked_prefill:
        raise ValueError(
            "engine uses the one-shot prefill fallback (SSM / MoE / "
            "multi-codebook): only decode_chunk exists as a chunked "
            "dispatch — audit a chunked-prefill arch instead")
    params_s = jax.tree.map(_sds, eng.params)
    cache_s = jax.tree.map(_sds, eng.cache)
    B, C = eng.B, eng.prefill_chunk
    lane_i32 = jax.ShapeDtypeStruct((B,), jnp.int32)
    lane_bool = jax.ShapeDtypeStruct((B,), jnp.bool_)
    toks = jax.ShapeDtypeStruct((B, C), jnp.int32)
    return {
        "reset": eng._reset_fn.lower(cache_s, lane_bool),
        "prefill_chunk": eng._prefill_chunk_fn.lower(
            params_s, cache_s, toks, lane_i32, lane_i32,
            ctx_pages=eng.prefill_pages),
        "decode_chunk": eng._chunk_fn.lower(
            params_s, cache_s, lane_i32, lane_i32, lane_bool, lane_i32,
            lane_i32, lane_i32, steps=eng.chunk_steps),
        "pool_transition": eng._transition_fn.lower(
            cache_s, lane_i32, lane_i32, lane_i32),
        # preemption restore: one lane's host checkpoint scattered back
        # into the donated cache.  The snapshot half is deliberately not
        # audited — it returns fresh single-lane rows (device->host by
        # design, and donating the cache it reads would be a bug).
        "lane_restore": eng._restore_fn.lower(
            cache_s, jax.ShapeDtypeStruct((), jnp.int32),
            jax.eval_shape(eng._snapshot_fn, cache_s,
                           jax.ShapeDtypeStruct((), jnp.int32))),
    }


def full_cache_elems(eng) -> int:
    """Element count of one full token-major copy of the paged KV cache
    (one layer): the classic threshold above which a transpose/gather
    in a dispatch is an O(S) copy, not bookkeeping."""
    k = eng.cache.per_pos[0].attn.k_pages
    # per-block caches are scan-stacked over layers: [L, B, KV, S, P, hd]
    B, KV, S, P, hd = k.shape[-5:]
    return B * KV * S * P * hd


def audit_engine(eng, *, min_donate_bytes: int = 1 << 16,
                 kv_copy_min_elems: Optional[Dict[str, int]] = None,
                 collective_budget: float = 0.0,
                 allow_undonated: Optional[Dict[str, str]] = None,
                 ) -> Tuple[List[Finding], Dict[str, Dict]]:
    """Compile the engine's dispatches and run every HLO pass.

    ``kv_copy_min_elems`` maps dispatch name -> copy threshold in
    elements (default: one full cache copy, :func:`full_cache_elems`);
    a dispatch mapped to 0/None skips the copy pass (e.g. the decode
    chunk of a policy whose *selection* is legitimately the whole O(L)
    cache).  Returns (findings, per-dispatch report of donation and
    collective accounting).
    """
    default_elems = full_cache_elems(eng)
    findings: List[Finding] = []
    report: Dict[str, Dict] = {}
    for name, lowered in dispatch_lowerings(eng).items():
        compiled = lowered.compile()
        text = compiled.as_text()
        min_elems = default_elems if kv_copy_min_elems is None \
            else kv_copy_min_elems.get(name, default_elems)
        if min_elems:
            findings.extend(hlo.kv_copy_findings(text, min_elems,
                                                 label=name))
        findings.extend(hlo.host_transfer_findings(text, label=name))
        findings.extend(hlo.collective_findings(
            text, max_bytes=collective_budget, label=name))
        findings.extend(hlo.donation_findings(
            text, min_bytes=min_donate_bytes, label=name,
            allow=allow_undonated))
        rep = hlo.donation_report(compiled)
        rep["collective_bytes"] = hlo.collective_bytes(text)["total"]
        report[name] = rep
    return findings, report
