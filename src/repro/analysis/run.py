"""``python -m repro.analysis.run`` — the whole static-analysis suite.

Layer 1 lints every module under ``src/repro`` (AST contracts, see
:mod:`repro.analysis.lint`).  Layer 2 compiles the serving engine's
jitted dispatches over a small config matrix (policy x dispatch, tiny
dense arch — the same shapes the serving tests pin down) and runs the
:mod:`repro.analysis.hlo` passes on each optimized program: KV-sized
copies, host transfers, collective traffic, the donation audit, plus
the jit-cache-growth guard over a real mini-workload's trace counters.

``--strict`` (the CI ``static-analysis`` leg) exits non-zero on any
finding.  ``--json`` dumps findings + the per-dispatch donation report
for dashboards.

The RaaS row deliberately skips the KV-copy pass: a RaaS policy's
cache is O(L) — its *selection* is the whole (small) cache, so the jnp
oracle's O(selection) decode gather is cache-sized by design, and one
prefill chunk's inherent attention intermediates (chunk x ctx) already
exceed the budgeted cache, so a cache-sized threshold cannot
discriminate.  The quest row, whose O(N) cache strictly dominates both,
carries the copy-size regression; donation / host-transfer /
collective passes still run on every row.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import engine_audit, hlo, lint
from repro.analysis.findings import Finding, format_findings

# tiny dense arch: the analysis matrix needs real engine dispatches,
# not a real model — same scale as the serving tests' TINY config.
_GEOMETRY = dict(batch_slots=4, max_seq=256, max_prefill=64,
                 prefill_chunk=16, chunk_steps=4)
_PAGE_SIZE = 16
_BUDGET = 64
DEFAULT_POLICIES = ("quest", "raas")


def _tiny_cfg():
    from repro.config import ModelConfig
    return ModelConfig(name="analysis-tiny", arch_type="dense",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128, head_dim=16)


def _mini_workload(eng, rng) -> None:
    """Serve a few multi-chunk prompts so the trace counters reflect a
    real schedule (prefill bucketing + decode chunks)."""
    from repro.serving.engine import Request
    from repro.serving.scheduler import serve
    reqs = [Request(uid=i, prompt=rng.integers(
        0, 128, size=n).astype(np.int32), max_new_tokens=5)
        for i, n in enumerate((40, 9, 33))]
    done = serve(eng, reqs)
    assert len(done) == len(reqs)


def analyze_engine_matrix(policies=DEFAULT_POLICIES,
                          min_donate_bytes: int = 1 << 16,
                          ) -> Tuple[List[Finding], Dict[str, Dict]]:
    """Compile + analyze the engine dispatch matrix; returns (findings,
    per-(policy, dispatch) donation/collective report)."""
    import jax
    from repro.config import RaasConfig
    from repro.models import model as M
    from repro.serving.engine import Engine

    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    findings: List[Finding] = []
    report: Dict[str, Dict] = {}
    for policy in policies:
        raas = RaasConfig(policy=policy, budget_tokens=_BUDGET,
                          page_size=_PAGE_SIZE, quest_topk_pages=3)
        eng = Engine(params, cfg, raas, **_GEOMETRY)
        _mini_workload(eng, np.random.default_rng(0))
        # trace counters BEFORE the audit: AOT lowering re-traces
        findings.extend(hlo.jit_cache_findings(
            prefill_traces=eng.prefill_traces,
            prefill_pages=eng.prefill_pages,
            decode_traces=eng.traces, distinct_decode_steps=1,
            label=f"engine[{policy}]"))
        thresholds = None
        if policy == "raas":
            thresholds = {"decode_chunk": 0, "prefill_chunk": 0}
        fs, rep = engine_audit.audit_engine(
            eng, min_donate_bytes=min_donate_bytes,
            kv_copy_min_elems=thresholds)
        findings.extend(Finding(f.rule, f"engine[{policy}]:{f.path}",
                                f.line, f.message, f.span) for f in fs)
        for name, r in rep.items():
            report[f"{policy}/{name}"] = r
    return findings, report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.run",
        description="repo static analysis: AST lint + compiled-HLO "
                    "invariant passes + donation audit")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any finding (the CI leg)")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="lint only — skip engine compilation passes")
    ap.add_argument("--root", default=None,
                    help="package root to lint (default: the installed "
                         "repro package)")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help="engine-matrix policies (comma list)")
    ap.add_argument("--min-donate-bytes", type=int, default=1 << 16,
                    help="donation-audit size floor (default 64 KiB)")
    ap.add_argument("--json", default=None,
                    help="write findings + donation report as JSON")
    args = ap.parse_args(argv)

    if args.root is None:
        import repro
        root = Path(repro.__file__).resolve().parent
    else:
        root = Path(args.root).resolve()

    lint_findings = lint.lint_tree(root)
    print(f"lint: {len(list(root.rglob('*.py')))} files under {root} — "
          f"{len(lint_findings)} finding(s)", flush=True)

    hlo_findings: List[Finding] = []
    report: Dict[str, Dict] = {}
    if not args.skip_hlo:
        policies = tuple(p for p in args.policies.split(",") if p)
        hlo_findings, report = analyze_engine_matrix(
            policies, min_donate_bytes=args.min_donate_bytes)
        print(f"hlo: engine matrix {policies} x "
              f"{engine_audit.DISPATCHES} — {len(hlo_findings)} "
              "finding(s)", flush=True)
        for key, rep in sorted(report.items()):
            print(f"  {key}: alias={rep['alias_bytes']} B "
                  f"peak_live={rep['peak_live_bytes']} B "
                  f"(undonated would be "
                  f"{rep['peak_live_bytes_undonated']} B), "
                  f"collectives={rep['collective_bytes']:.0f} B",
                  flush=True)

    findings = lint_findings + hlo_findings
    if findings:
        print(format_findings(findings), flush=True)
    else:
        print("OK: no findings", flush=True)

    if args.json:
        Path(args.json).write_text(json.dumps({
            "findings": [vars(f) for f in findings],
            "donation_report": report,
        }, indent=2) + "\n")

    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
