"""The one finding type every analysis pass emits.

A finding pins a *named rule* to a *span* (file + line for source
lint, HLO instruction text for compiled-program passes) with a
human-actionable message.  Passes never print or raise — they return
findings, and the caller (CLI, test, benchmark) decides severity.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # stable kebab-case rule id, e.g. "kv-copy"
    path: str            # repo-relative file, or a dispatch label
    line: int            # 1-based source / HLO-text line (0 = whole file)
    message: str         # what is wrong and why it matters
    span: str = ""       # the offending source / HLO line, trimmed

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.span:
            out += f"\n    | {self.span}"
        return out


def format_findings(findings: Iterable[Finding]) -> str:
    lines: List[str] = [f.format() for f in findings]
    return "\n".join(lines)
