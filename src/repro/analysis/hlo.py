"""Passes over optimized HLO / compiled programs: the hot-path
invariants behind the repo's zero-copy claims, as reusable analyzers.

Every pass is pure text/metadata analysis — it takes the optimized HLO
of a compiled program (``compiled.as_text()``) and returns
:class:`~repro.analysis.findings.Finding` objects (or raw dicts for
the accountants).  The passes are the single home of heuristics that
used to live as private parsers in tests/test_zero_copy.py,
tests/test_paged_prefill.py and launch/hlo_analysis.py:

* **KV-sized-copy detector** (:func:`kv_copy_ops`,
  :func:`kv_copy_findings`) — float transpose/gather instructions at or
  above a KV-copy threshold: page selection must reach kernels as
  indices, never as copied tensors.
* **Host-transfer detector** (:func:`host_transfer_findings`) —
  infeed/outfeed/send/recv, host custom-calls and non-default memory
  spaces; a compiled dispatch must never bounce through the host.
* **Collective accountant** (:func:`collective_bytes`,
  :func:`count_collectives`, :func:`collective_findings`) — ring-model
  per-device link bytes by collective kind, plus a budget check.
* **Donation auditor** (:func:`donation_findings`,
  :func:`donation_report`) — large pass-through buffers (the paged
  cache above all) handed to a jitted dispatch without
  ``donate_argnums``: each one holds TWO live copies of the buffer
  across the dispatch instead of one.
* **Jit-cache-growth guard** (:func:`jit_cache_findings`) — trace
  counts against the engine's power-of-two bucketing bound; unbounded
  recompiles are a serving memory leak.

Ring-model bytes-on-the-wire per device, for group size g and result
payload R bytes:
  all-gather          (g-1)/g * R        (R is the gathered result)
  all-reduce          2*(g-1)/g * R      (reduce-scatter + all-gather)
  reduce-scatter      (g-1) * R          (R is the scattered result)
  all-to-all          (g-1)/g * R
  collective-permute  R
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return max(1, int(m.group(2)))
    return 1


# ---------------------------------------------------------------------------
# collective accountant
# ---------------------------------------------------------------------------
def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device link bytes by collective kind + 'total'."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[^=]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        payload = _shape_bytes(shape_str)
        g = _group_size(s)
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            traffic = payload * (g - 1) / g
        elif kind == "all-reduce":
            traffic = payload * 2 * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = payload * (g - 1)
        elif kind == "all-to-all":
            traffic = payload * (g - 1) / g
        else:
            traffic = payload
        out[kind] += traffic
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for kind in _COLLECTIVES:
        counts[kind] = len(re.findall(rf"\b{kind}(?:-start)?\(", hlo_text))
    return counts


def collective_findings(hlo_text: str, max_bytes: float = 0.0,
                        label: str = "hlo") -> List[Finding]:
    """Budget check: total per-device collective traffic above
    ``max_bytes`` is a finding (0 = the dispatch must be
    collective-free, the single-device hot-path contract)."""
    coll = collective_bytes(hlo_text)
    if coll["total"] <= max_bytes:
        return []
    detail = ", ".join(f"{k}={v:.0f}B" for k, v in coll.items()
                       if k != "total" and v)
    return [Finding(
        rule="collective-traffic", path=label, line=0,
        message=f"dispatch moves {coll['total']:.0f} collective bytes "
                f"per device (budget {max_bytes:.0f}): {detail}")]


# v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    return {
        "compute_s": flops_per_device / PEAK_FLOPS_BF16,
        "memory_s": bytes_per_device / HBM_BW,
        "collective_s": coll_bytes_per_device / ICI_BW,
    }


# ---------------------------------------------------------------------------
# KV-sized-copy detector
# ---------------------------------------------------------------------------
_COPY_OP = re.compile(
    r"=\s*(f32|bf16|f16)\[([\d,]*)\][^ ]*\s+(transpose|gather)\(")


def kv_copy_ops(hlo_text: str, min_elems: int
                ) -> List[Tuple[str, Tuple[int, ...], int, str]]:
    """(op, dims, line_no, line) of float transpose/gather instructions
    whose output holds >= ``min_elems`` elements — the shape of a
    materialized KV copy the zero-copy kernels exist to avoid."""
    found = []
    for no, line in enumerate(hlo_text.splitlines(), start=1):
        m = _COPY_OP.search(line)
        if not m:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        n = 1
        for d in dims:
            n *= d
        if n >= min_elems:
            found.append((m.group(3), dims, no, line.strip()))
    return found


def kv_copy_findings(hlo_text: str, min_elems: int,
                     label: str = "hlo") -> List[Finding]:
    return [Finding(
        rule="kv-copy", path=label, line=no,
        message=f"{op} materializes {dims} "
                f"(>= {min_elems} elements) — a KV-sized copy on a "
                "path that must consume the cache in place",
        span=span)
        for op, dims, no, span in kv_copy_ops(hlo_text, min_elems)]


# ---------------------------------------------------------------------------
# host-transfer detector
# ---------------------------------------------------------------------------
_HOST_OP = re.compile(
    r"=\s*\(?[^=]*?\s*(infeed|outfeed|send|recv)(-start|-done)?\(")
_CUSTOM_CALL_TARGET = re.compile(r'custom_call_target="([^"]*)"')
_MEM_SPACE = re.compile(r"\{[\d,]*:[^}]*S\((\d+)\)")


def host_transfer_findings(hlo_text: str,
                           label: str = "hlo") -> List[Finding]:
    """Ops that move bytes between device and host inside a compiled
    program: infeed/outfeed, send/recv, host custom-calls
    (MoveToHost and friends) and buffers annotated into a non-default
    memory space.  The hot path syncs at dispatch boundaries only — a
    transfer *inside* the program serializes every step."""
    out: List[Finding] = []
    for no, line in enumerate(hlo_text.splitlines(), start=1):
        s = line.strip()
        m = _HOST_OP.search(s)
        if m and m.group(2) != "-done":
            out.append(Finding(
                rule="host-transfer", path=label, line=no,
                message=f"`{m.group(1)}` op inside the compiled program "
                        "— host I/O on the hot path", span=s))
            continue
        m = _CUSTOM_CALL_TARGET.search(s)
        if m and re.search(r"(?i)host", m.group(1)):
            out.append(Finding(
                rule="host-transfer", path=label, line=no,
                message=f"host custom-call `{m.group(1)}` — buffer "
                        "migration to host inside the program", span=s))
            continue
        m = _MEM_SPACE.search(s)
        if m and m.group(1) != "0":
            out.append(Finding(
                rule="host-transfer", path=label, line=no,
                message=f"buffer placed in memory space S({m.group(1)}) "
                        "— off-device residency on the hot path", span=s))
    return out


# ---------------------------------------------------------------------------
# donation auditor
# ---------------------------------------------------------------------------
def _split_top_level(s: str) -> List[str]:
    """Split on commas not nested in (), [] or {}."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _extract_braced(text: str, key: str) -> Optional[str]:
    """The balanced ``{...}`` payload following ``key=`` (sans braces)."""
    start = text.find(key + "={")
    if start < 0:
        return None
    i = start + len(key) + 1
    depth = 0
    for j in range(i, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                return text[i + 1:j]
    return None


def _norm_shape(tok: str) -> str:
    """'f32[4,2]{1,0}' -> 'f32[4,2]' (layout/memory-space stripped)."""
    m = _SHAPE_RE.search(tok)
    return f"{m.group(1)}[{m.group(2)}]" if m else tok


def entry_params_and_outputs(hlo_text: str
                             ) -> Tuple[List[str], List[str]]:
    """Normalized parameter and output shapes of the entry computation,
    in declaration order, from ``entry_computation_layout``."""
    layout = _extract_braced(hlo_text, "entry_computation_layout")
    if layout is None:
        raise ValueError("no entry_computation_layout in HLO text")
    lhs, _, rhs = layout.partition("->")
    lhs, rhs = lhs.strip(), rhs.strip()
    if lhs.startswith("("):
        lhs = lhs[1:lhs.rfind(")")]
    if rhs.startswith("("):
        rhs = rhs[1:rhs.rfind(")")]
    params = [_norm_shape(t) for t in _split_top_level(lhs) if t]
    outs = [_norm_shape(t) for t in _split_top_level(rhs) if t]
    return params, outs


_ALIAS_ENTRY = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+)")


def donated_params(hlo_text: str) -> Dict[int, int]:
    """param_number -> output index for every ``input_output_alias``
    entry of the module header (empty when nothing is donated)."""
    block = _extract_braced(hlo_text, "input_output_alias")
    if block is None:
        return {}
    out: Dict[int, int] = {}
    for m in _ALIAS_ENTRY.finditer(block):
        out_idx = m.group(1).split(",")[0].strip()
        out[int(m.group(2))] = int(out_idx) if out_idx else 0
    return out


def donation_findings(hlo_text: str, min_bytes: int,
                      label: str = "hlo",
                      allow: Optional[Dict[str, str]] = None
                      ) -> List[Finding]:
    """Large un-donated pass-through buffers in a compiled program.

    A parameter of at least ``min_bytes`` with no ``input_output_alias``
    entry, while an identically-shaped un-aliased output exists, is a
    buffer the caller consumes and re-materializes every dispatch
    (e.g. the paged cache threaded through reset / prefill_chunk /
    decode_chunk): donating it halves the buffer's peak live copies.
    Persistent inputs with no matching output (model params) are not
    flagged — there is nothing to alias them onto.

    ``allow`` maps a normalized shape (e.g. ``"f32[4,2,24,16,16]"``) to
    a one-line justification for deliberately un-donated buffers.
    """
    params, outs = entry_params_and_outputs(hlo_text)
    donated = donated_params(hlo_text)
    free_outputs: Dict[str, int] = {}
    aliased_out_idx = set(donated.values())
    for i, shape in enumerate(outs):
        if i not in aliased_out_idx:
            free_outputs[shape] = free_outputs.get(shape, 0) + 1
    findings: List[Finding] = []
    for i, shape in enumerate(params):
        if i in donated:
            continue
        size = _shape_bytes(shape)
        if size < min_bytes:
            continue
        if allow and shape in allow:
            continue
        if free_outputs.get(shape, 0) > 0:
            free_outputs[shape] -= 1
            findings.append(Finding(
                rule="undonated-buffer", path=label, line=0,
                message=f"parameter {i} ({shape}, {size} B) passes "
                        "through un-donated — an identically-shaped "
                        "output exists, so donate_argnums would alias "
                        "it and drop one live copy per dispatch"))
    return findings


def donation_report(compiled) -> Dict[str, int]:
    """Measured donation effect of one compiled dispatch, from XLA's
    buffer assignment: ``alias_bytes`` is what donation saves, and
    ``peak_live_bytes`` is argument + output + temp − alias (what the
    same dispatch would hold live without donation is
    ``peak_live_bytes_undonated``)."""
    m = compiled.memory_analysis()
    arg = int(getattr(m, "argument_size_in_bytes", 0))
    out = int(getattr(m, "output_size_in_bytes", 0))
    tmp = int(getattr(m, "temp_size_in_bytes", 0))
    alias = int(getattr(m, "alias_size_in_bytes", 0))
    return {
        "argument_bytes": arg,
        "output_bytes": out,
        "temp_bytes": tmp,
        "alias_bytes": alias,
        "peak_live_bytes": arg + out + tmp - alias,
        "peak_live_bytes_undonated": arg + out + tmp,
    }


# ---------------------------------------------------------------------------
# jit-cache-growth guard
# ---------------------------------------------------------------------------
def jit_cache_findings(*, prefill_traces: int, prefill_pages: int,
                       decode_traces: int, distinct_decode_steps: int,
                       label: str = "engine") -> List[Finding]:
    """The engine's compile counts against its own bucketing contract:
    power-of-two ``ctx_pages`` bucketing bounds prefill variants at
    log2(prefill_pages) + 1, and the decode chunk compiles once per
    distinct static ``steps`` value.  Anything beyond is unbounded
    jit-cache growth — a serving memory leak."""
    findings: List[Finding] = []
    bound = max(prefill_pages, 1).bit_length() + 1
    if prefill_traces > bound:
        findings.append(Finding(
            rule="jit-cache-growth", path=label, line=0,
            message=f"{prefill_traces} prefill compilations for "
                    f"{prefill_pages} prefill pages (bucketing bound: "
                    f"{bound}) — ctx_pages bucketing is broken"))
    if decode_traces > max(distinct_decode_steps, 1):
        findings.append(Finding(
            rule="jit-cache-growth", path=label, line=0,
            message=f"{decode_traces} decode-chunk compilations for "
                    f"{distinct_decode_steps} distinct chunk lengths — "
                    "a non-static argument is leaking into the trace"))
    return findings
