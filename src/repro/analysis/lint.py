"""AST lint over ``src/``: the repo's kernel / serving contracts as
named, suppressible rules.

Rules (stable ids — suppressions and CI reference them):

``pallas-call-outside-kernels``
    ``pl.pallas_call`` may appear only under ``kernels/``.  Everything
    above the kernel layer talks to Pallas through ``kernels.ops``, so
    the zero-copy HLO regressions watch one module, not the whole tree.
``pallas-missing-interpret``
    Every raw Pallas entry (``pallas_call`` itself, and any call to a
    ``*_pallas`` kernel wrapper) must thread an explicit ``interpret=``
    kwarg.  ``ops.py`` alone maps ``impl`` to an execution mode; a call
    that omits the kwarg could silently run interpreted on TPU.
``host-sync-in-dispatch-loop``
    Inside ``serving/``: no ``.item()`` / ``jax.device_get`` anywhere,
    and no ``np.asarray`` / ``float()`` / ``int()`` / ``bool()`` *of a
    jnp expression* inside a ``for``/``while`` body.  The engine syncs
    host state once per dispatch at chunk boundaries — a per-lane
    round-trip in a loop serializes the device queue.
``paged-gather-outside-kernels``
    No fancy-index (advanced) subscript load of the paged-cache KV
    arrays (``k_pages`` / ``v_pages``) outside ``kernels/``.  Page
    selection reaches the kernels as an i32 index table; a gather
    anywhere else re-materializes KV bytes the kernels exist to avoid.
``policy-imports``
    Files in ``core/policies/`` import only ``policy_base`` (plus
    sibling policies, jax/numpy and the stdlib).  A policy is one
    self-contained file; reaching into cache or engine internals
    couples it to layouts the registry promises to insulate it from.
``pool-refcount-outside-pool``
    The page pool's ``refcount`` column may be mutated only inside
    ``core/paged_cache.py`` and ``core/page_pool.py``: no
    ``refcount=`` keyword in a call and no ``.refcount.at[...]``
    update chain anywhere else.  Every other layer reasons in lane
    *transitions* (mount / incref / release / reset) — a raw count
    write outside the pool would silently break the no-eviction
    guarantee on shared slots that the property tests pin down.
``no-bare-except-in-serving``
    Inside ``serving/``: no bare ``except:`` and no except handler
    whose body is a single ``pass``.  The resilience layer's contract
    is that every failure reaches a terminal request status or
    propagates to the scheduler's drain path — a silent swallow in
    serving code is exactly how a dispatch error turns into a leaked
    lane.  Handlers must name the exception type and *do* something.
``no-unbounded-retry``
    Inside ``serving/``: no ``while True:`` (or ``while 1:``) loop
    containing a ``try`` statement.  Retry-on-error must be bounded
    (``for attempt in range(retry_limit)`` — see
    ``Engine._dispatch``); an unbounded retry loop around a dispatch
    converts a permanent fault into a livelock.

Suppression syntax — on the offending line, or a standalone comment on
the line directly above::

    x = cache.k_pages[b, :, slots]  # analysis: allow=<rule-id> -- <one-line why>

The justification after ``--`` is mandatory (``bare-suppression``
otherwise); a suppression that no finding consumed is itself reported
(``unused-suppression``), so stale exemptions cannot linger.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

RULES = (
    "pallas-call-outside-kernels",
    "pallas-missing-interpret",
    "host-sync-in-dispatch-loop",
    "paged-gather-outside-kernels",
    "policy-imports",
    "pool-refcount-outside-pool",
    "no-bare-except-in-serving",
    "no-unbounded-retry",
)

# the only modules allowed to touch PagedCache.refcount directly
_POOL_OWNERS = (("core", "paged_cache.py"), ("core", "page_pool.py"))

_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*allow=([\w-]+)(?:\s*--\s*(\S.*))?")

_PAGED_ARRAYS = ("k_pages", "v_pages")
_POLICY_IMPORT_OK = ("__future__", "typing", "dataclasses", "functools",
                     "math", "jax", "numpy",
                     "repro.core.policy_base", "repro.core.policies")


def _terminal_name(node: ast.expr) -> Optional[str]:
    """'pallas_call' for ``pl.pallas_call`` / ``pallas_call``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted_name(node: ast.expr) -> Optional[str]:
    """'jax.device_get' for a pure Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_jnp(node: ast.expr) -> bool:
    """Does the expression subtree touch ``jnp.*`` (a device value)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id == "jnp":
            return True
    return False


def _is_advanced_index(sl: ast.expr) -> bool:
    """Advanced (fancy) indexing: any index element that is not a
    slice / constant scalar — a Name, Call or array expression there
    makes XLA gather."""
    elems = sl.elts if isinstance(sl, ast.Tuple) else [sl]
    for e in elems:
        if isinstance(e, ast.Slice):
            continue
        if isinstance(e, ast.Constant):
            continue
        if isinstance(e, ast.UnaryOp) and isinstance(e.operand,
                                                     ast.Constant):
            continue
        return True
    return False


class _FileLint:
    def __init__(self, path: Path, rel: str, src: str):
        self.path = path
        self.rel = rel                        # posix path relative to root
        self.parts = tuple(Path(rel).parts)
        self.src_lines = src.splitlines()
        self.tree = ast.parse(src, filename=str(path))
        self.findings: List[Finding] = []

    # -- helpers -----------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        span = self.src_lines[line - 1].strip() if line else ""
        self.findings.append(Finding(rule=rule, path=self.rel, line=line,
                                     message=message, span=span))

    @property
    def in_kernels(self) -> bool:
        return "kernels" in self.parts

    @property
    def in_serving(self) -> bool:
        return "serving" in self.parts

    @property
    def is_policy_file(self) -> bool:
        return ("policies" in self.parts
                and self.parts[-1] != "__init__.py")

    @property
    def owns_refcount(self) -> bool:
        return self.parts[-2:] in [tuple(p) for p in _POOL_OWNERS]

    # -- walk --------------------------------------------------------------
    def run(self) -> List[Finding]:
        if self.is_policy_file:
            self._check_policy_imports()
        self._walk(self.tree, loop_depth=0)
        return self.findings

    def _walk(self, node: ast.AST, loop_depth: int) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Call):
                self._check_call(child, loop_depth)
            d = loop_depth + (1 if isinstance(child,
                                              (ast.For, ast.While)) else 0)
            if isinstance(child, ast.Subscript) \
                    and isinstance(child.ctx, ast.Load):
                self._check_subscript(child)
            if self.in_serving:
                if isinstance(child, ast.ExceptHandler):
                    self._check_except(child)
                elif isinstance(child, ast.While):
                    self._check_retry_loop(child)
            self._walk(child, d)

    # -- rules -------------------------------------------------------------
    def _check_call(self, call: ast.Call, loop_depth: int) -> None:
        name = _terminal_name(call.func)
        if name is None:
            return
        kwargs = {kw.arg for kw in call.keywords}
        if "refcount" in kwargs and not self.owns_refcount:
            self._emit("pool-refcount-outside-pool", call,
                       "refcount= passed outside the pool modules — "
                       "claims move only via page_pool lane transitions "
                       "(mount/incref/release/reset)")
        if name == "pallas_call" and not self.in_kernels:
            self._emit("pallas-call-outside-kernels", call,
                       "pallas_call outside kernels/ — raw kernels live "
                       "under kernels/ and are reached via kernels.ops")
        if (name == "pallas_call" or name.endswith("_pallas")) \
                and "interpret" not in kwargs:
            self._emit("pallas-missing-interpret", call,
                       f"raw Pallas entry `{name}` called without an "
                       "explicit interpret= kwarg (ops.py alone picks "
                       "the execution mode)")
        if name == "take_along_axis" or name == "take":
            for arg in call.args[:1]:
                t = _terminal_name(arg)
                if t in _PAGED_ARRAYS and not self.in_kernels:
                    self._emit("paged-gather-outside-kernels", call,
                               f"jnp.{name} on PagedCache array `{t}` "
                               "outside kernels/ — selection must reach "
                               "the kernel as an index table")
        if self.in_serving:
            self._check_host_sync(call, name, loop_depth)

    def _check_host_sync(self, call: ast.Call, name: str,
                         loop_depth: int) -> None:
        dotted = _dotted_name(call.func)
        if name == "item" and isinstance(call.func, ast.Attribute) \
                and not call.args:
            self._emit("host-sync-in-dispatch-loop", call,
                       ".item() in serving code — a blocking device "
                       "round-trip; sync whole arrays once per dispatch")
            return
        if dotted == "jax.device_get":
            self._emit("host-sync-in-dispatch-loop", call,
                       "jax.device_get in serving code — transfer whole "
                       "chunk outputs at the dispatch boundary instead")
            return
        if loop_depth == 0:
            return
        sync = dotted in ("np.asarray", "numpy.asarray") \
            or (isinstance(call.func, ast.Name)
                and call.func.id in ("float", "int", "bool"))
        if sync and call.args and _mentions_jnp(call.args[0]):
            self._emit("host-sync-in-dispatch-loop", call,
                       f"`{dotted or _terminal_name(call.func)}` of a jnp "
                       "value inside a loop — one host sync per "
                       "iteration; batch the transfer outside the loop")

    def _check_except(self, handler: ast.ExceptHandler) -> None:
        if handler.type is None:
            self._emit("no-bare-except-in-serving", handler,
                       "bare `except:` in serving code — name the "
                       "exception; failures must reach a terminal "
                       "request status, never vanish")
            return
        if len(handler.body) == 1 \
                and isinstance(handler.body[0], ast.Pass):
            self._emit("no-bare-except-in-serving", handler,
                       "except handler silently swallows (`pass` "
                       "body) in serving code — handle the failure "
                       "or let the scheduler's drain path see it")

    def _check_retry_loop(self, loop: ast.While) -> None:
        test = loop.test
        endless = (isinstance(test, ast.Constant)
                   and (test.value is True or test.value == 1))
        if endless and any(isinstance(n, ast.Try)
                           for n in ast.walk(loop)):
            self._emit("no-unbounded-retry", loop,
                       "`while True:` around a try in serving code — "
                       "retry must be bounded (for attempt in "
                       "range(retry_limit)), or a permanent fault "
                       "becomes a livelock")

    def _check_subscript(self, sub: ast.Subscript) -> None:
        v = sub.value
        if (isinstance(v, ast.Attribute) and v.attr == "at"
                and isinstance(v.value, ast.Attribute)
                and v.value.attr == "refcount"
                and not self.owns_refcount):
            self._emit("pool-refcount-outside-pool", sub,
                       ".refcount.at[...] update outside the pool "
                       "modules — claims move only via page_pool lane "
                       "transitions (mount/incref/release/reset)")
        if self.in_kernels:
            return
        t = _terminal_name(sub.value)
        if t not in _PAGED_ARRAYS:
            return
        if _is_advanced_index(sub.slice):
            self._emit("paged-gather-outside-kernels", sub,
                       f"fancy-index gather on PagedCache array `{t}` "
                       "outside kernels/ — pages must reach the kernels "
                       "as indices, never as a copied tensor")

    def _check_policy_imports(self) -> None:
        for node in self._runtime_imports(self.tree):
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            else:
                if node.level > 0:
                    # relative import inside core/policies/ stays inside
                    # the package (or one level up = core.policy_base)
                    mod = node.module or ""
                    if node.level == 1 or mod.startswith("policy_base"):
                        continue
                    mods = [f"<rel:{'.' * node.level}{mod}>"]
                else:
                    mods = [node.module or ""]
            for mod in mods:
                if any(mod == ok or mod.startswith(ok + ".")
                       for ok in _POLICY_IMPORT_OK):
                    continue
                self._emit("policy-imports", node,
                           f"policy file imports `{mod}` — policies may "
                           "import only policy_base (and sibling "
                           "policies); shared constants belong on "
                           "policy_base")

    def _runtime_imports(self, tree: ast.Module) -> Iterator[ast.stmt]:
        """Module-level imports outside ``if TYPE_CHECKING:`` blocks."""
        for node in tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                yield node
            elif isinstance(node, ast.If):
                test = node.test
                is_tc = (isinstance(test, ast.Name)
                         and test.id == "TYPE_CHECKING") \
                    or (isinstance(test, ast.Attribute)
                        and test.attr == "TYPE_CHECKING")
                if not is_tc:
                    for sub in node.body:
                        if isinstance(sub, (ast.Import, ast.ImportFrom)):
                            yield sub


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def _suppressions(src_lines: Sequence[str]
                  ) -> Dict[int, Tuple[str, str]]:
    """line -> (rule, justification) for every allow marker."""
    out: Dict[int, Tuple[str, str]] = {}
    for i, line in enumerate(src_lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = (m.group(1), (m.group(2) or "").strip())
    return out


def _apply_suppressions(findings: List[Finding], rel: str,
                        src_lines: Sequence[str]) -> List[Finding]:
    """Drop findings covered by a justified allow marker; report bare,
    unknown and unused markers as findings themselves."""
    sup = _suppressions(src_lines)
    used = set()
    kept: List[Finding] = []
    for f in findings:
        covering = None
        if f.line in sup and sup[f.line][0] == f.rule:
            covering = f.line
        else:
            prev = f.line - 1
            if prev in sup and sup[prev][0] == f.rule \
                    and src_lines[prev - 1].lstrip().startswith("#"):
                covering = prev
        if covering is not None and sup[covering][1]:
            used.add(covering)
        else:
            kept.append(f)
    for line, (rule, why) in sorted(sup.items()):
        span = src_lines[line - 1].strip()
        if rule not in RULES:
            kept.append(Finding("unknown-suppression", rel, line,
                                f"allow marker names unknown rule "
                                f"`{rule}`", span))
        elif not why:
            kept.append(Finding("bare-suppression", rel, line,
                                f"allow={rule} without a justification "
                                "— add `-- <why this is safe>`", span))
        elif line not in used:
            kept.append(Finding("unused-suppression", rel, line,
                                f"allow={rule} suppresses nothing — "
                                "remove the stale marker", span))
    return kept


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def lint_file(path: Path, root: Path) -> List[Finding]:
    rel = path.relative_to(root).as_posix()
    src = path.read_text()
    findings = _FileLint(path, rel, src).run()
    return _apply_suppressions(findings, rel, src.splitlines())


def lint_tree(root: Path) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (the ``src/repro`` package)."""
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        findings.extend(lint_file(path, root))
    return findings
