"""SmolLM-360M — small llama-architecture dense GQA.  [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=1e4,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
