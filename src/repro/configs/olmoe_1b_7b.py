"""OLMoE-1B-7B — 64-expert top-8 MoE, MHA-style GQA (kv=16).  [arXiv:2409.02060]"""
from repro.config import ModelConfig, MoEConfig, ATTN, FFN_MOE

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=50304,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e4,
    period=((ATTN, FFN_MOE),),
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024),
    source="arXiv:2409.02060",
)
