"""Mamba2-780m — attention-free SSM via SSD (state-space duality).

[arXiv:2405.21060].  48 layers, d_model 1536, d_state 128, expand 2,
head_dim 64 (n_heads = 48).  No attention layers -> the RaaS policy is
inapplicable (no KV cache exists); see DESIGN.md §Arch-applicability.
"""
from repro.config import ModelConfig, MambaConfig, MAMBA

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    period=((MAMBA, "none"),),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
    source="arXiv:2405.21060",
)
