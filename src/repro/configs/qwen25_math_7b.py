"""Qwen2.5-Math-7B-shaped config — the paper's own eval model family.

[arXiv:2409.12122] — used by the RaaS paper for the waterfall-pattern
analysis (28L x 28H) and the accuracy benchmarks.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen25-math-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    rope_theta=1e4,
    source="arXiv:2409.12122",
)
