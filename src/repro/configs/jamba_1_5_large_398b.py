"""Jamba-1.5-Large (398B) — hybrid Mamba+attention with MoE.

[arXiv:2403.19887].  72 layers, 1:7 attention:mamba interleave (one
attention layer per 8-layer period, placed at index 4 within the period
following the Jamba paper's mid-period placement), MoE 16 experts top-2
on every other layer.
"""
from repro.config import ModelConfig, MoEConfig, MambaConfig, ATTN, MAMBA, FFN_DENSE, FFN_MOE

# period of 8 layers: mamba everywhere except index 4; MoE on odd indices.
_PERIOD = tuple(
    (ATTN if i == 4 else MAMBA, FFN_MOE if i % 2 == 1 else FFN_DENSE)
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    rope_theta=1e6,
    period=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=128,
                      chunk_size=256),
    source="arXiv:2403.19887",
)
