"""Yi-34B — llama-architecture dense GQA.  [arXiv:2403.04652]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5e6,
    source="arXiv:2403.04652",
)
