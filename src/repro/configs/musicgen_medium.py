"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284].  48L d_model 1536, 24 heads MHA (kv=24), FFN 6144,
4 EnCodec codebooks of 2048 entries each (delay interleave pattern);
codebook embeddings are summed at the input and 4 parallel LM heads
produce per-codebook logits.  The EnCodec conv codec itself is a STUB
per the assignment carve-out — ``input_specs`` supplies token ids (and
optional conditioning prefix embeddings).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    rope_theta=1e4,
    frontend="encodec_stub",
    n_codebooks=4,
    source="arXiv:2306.05284",
)
