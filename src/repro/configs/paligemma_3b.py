"""PaliGemma-3B — gemma-style decoder consuming SigLIP patch embeddings.

[arXiv:2407.07726].  The SigLIP vision tower + projector is a STUB per
the assignment carve-out: ``input_specs`` supplies precomputed patch
embeddings (224px / patch14 -> 256 patches) of shape
``[batch, 256, d_model]``; this config defines the language decoder
(gemma-2b: 18L, d_model 2048, MQA with 1 KV head, head_dim 256,
gelu-gated FFN 16384).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    rope_theta=1e4,
    tie_embeddings=True,
    frontend="siglip_stub",
    n_prefix_tokens=256,
    source="arXiv:2407.07726",
)
