"""Kimi-K2 — trillion-parameter MoE, 384 experts top-8 (paper table).

[arXiv:2501.kimi2].  61L d_model 7168, 64 query heads / 8 KV heads
(paper-table GQA figure), per-expert FFN width 2048, vocab 163840.
"""
from repro.config import ModelConfig, MoEConfig, ATTN, FFN_MOE

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=163840,
    head_dim=112,
    rope_theta=5e6,
    period=((ATTN, FFN_MOE),),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048),
    source="arXiv:2501.kimi2",
)
