"""Batched serving engine with slot-based continuous batching.

The paper's deployment story: a decode-dominated engine where each
sequence's KV cache is a *fixed-size* RaaS-managed region (O(L) per
slot), so the engine's total memory is ``batch_slots * L`` regardless
of how long any chain-of-thought runs — this is the "significantly
higher throughput" claim of paper §4.3.

Design:
  * ``batch_slots`` fixed decode lanes; the scheduler (scheduler.py)
    assigns queued requests to free lanes.
  * Prefill runs one request at a time (prompts padded to
    ``max_prefill``), its cache rows are spliced into the lane.
  * The decode hot path is *chunked*: one jitted dispatch of
    ``models.model.decode_chunk`` advances every active lane by up to
    ``chunk_steps`` tokens — greedy sampling, EOS / length stopping and
    position bookkeeping all happen on device, and the host only syncs
    at chunk boundaries (where the scheduler admits / frees lanes).
  * Lane KV lives in the page-major kernel-native cache layout
    (``[B, KV, S, P, hd]``); splicing a prefilled row into a lane and
    every decode step are in-place page writes — the engine never
    re-lays-out KV bytes.
  * All policy semantics dispatch through the resolved
    :class:`SparsityPolicy` object; the engine knows no policy names.

``dispatches`` counts jitted decode dispatches issued (one per chunk);
``traces`` counts compilations of the chunk function (one per distinct
chunk length) — the trace-count test asserts chunks hit the jit cache.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RaasConfig
from repro.core.policy_base import get_policy
from repro.models import model as M


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, raas: RaasConfig,
                 batch_slots: int = 4, max_seq: int = 1024,
                 max_prefill: int = 128, impl: str = "jnp",
                 param_dtype=jnp.float32, chunk_steps: int = 8):
        self.policy = get_policy(raas.policy)
        raas = self.policy.finalize_config(raas, max_prefill)
        self.params = params
        self.cfg = cfg
        self.raas = raas
        self.B = batch_slots
        self.max_seq = max_seq
        self.max_prefill = max_prefill
        self.impl = impl
        self.chunk_steps = chunk_steps

        self.cache = M.init_model_cache(cfg, raas, batch_slots, max_seq,
                                        prefill_len=max_prefill,
                                        dtype=param_dtype)
        self._fresh_row = M.init_model_cache(cfg, raas, 1, max_seq,
                                             prefill_len=max_prefill,
                                             dtype=param_dtype)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.last_token = np.zeros(batch_slots, np.int32)
        self.active = np.zeros(batch_slots, bool)
        self.n_emitted = np.zeros(batch_slots, np.int32)
        self.eos_id = np.full(batch_slots, -1, np.int32)
        self.max_new = np.zeros(batch_slots, np.int32)
        self.steps_executed = 0     # decode steps (tokens per lane)
        self.dispatches = 0         # jitted chunk dispatches issued
        self.traces = 0             # chunk-fn compilations

        raas_cfg, cfg_, impl_, policy = raas, cfg, impl, self.policy

        @jax.jit
        def _prefill(params, cache_row, tokens, length):
            return M.prefill(params, cfg_, tokens, length, cache_row,
                             impl=impl_)

        def _chunk(params, cache, token, pos, active, n_emitted,
                   eos_id, max_new, steps):
            self.traces += 1        # runs at trace time only
            return M.decode_chunk(params, cfg_, cache, token, pos,
                                  active, n_emitted, eos_id, max_new,
                                  raas_cfg, steps=steps,
                                  max_seq=self.max_seq, impl=impl_,
                                  policy=policy)

        self._prefill_fn = _prefill
        self._chunk_fn = jax.jit(_chunk, static_argnames=("steps",))

    # -- slot management -----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def has_active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def _splice_row(self, slot: int, row_cache) -> None:
        self.cache = jax.tree.map(
            lambda full, row: full.at[:, slot].set(row[:, 0]),
            self.cache, row_cache)

    def admit(self, req: Request) -> None:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        L = min(len(req.prompt), self.max_prefill)
        toks = np.zeros((1, self.max_prefill), np.int32)
        toks[0, :L] = req.prompt[:L]
        row = jax.tree.map(lambda x: x, self._fresh_row)
        row_cache, logits = self._prefill_fn(
            self.params, row, jnp.asarray(toks),
            jnp.asarray([L], jnp.int32))
        self._splice_row(slot, row_cache)
        nxt = int(jnp.argmax(logits[0], axis=-1).reshape(-1)[0])
        self.slot_req[slot] = req
        self.pos[slot] = L
        self.last_token[slot] = nxt
        self.active[slot] = True
        self.n_emitted[slot] = 1
        self.eos_id[slot] = -1 if req.eos_id is None else req.eos_id
        self.max_new[slot] = req.max_new_tokens
        req.output.append(nxt)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        self.slot_req[slot] = None

    # -- decode ----------------------------------------------------------------
    def step_chunk(self, steps: Optional[int] = None) -> List[Request]:
        """Advance every active lane by up to ``steps`` tokens in ONE
        jitted dispatch; sync host state at the boundary and free
        finished lanes.  Returns the requests that finished."""
        steps = self.chunk_steps if steps is None else steps
        slots = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not slots:
            return []
        self.dispatches += 1
        self.cache, out = self._chunk_fn(
            self.params, self.cache,
            jnp.asarray(self.last_token), jnp.asarray(self.pos),
            jnp.asarray(self.active), jnp.asarray(self.n_emitted),
            jnp.asarray(self.eos_id), jnp.asarray(self.max_new),
            steps=steps)
        toks = np.asarray(out.tokens)          # [K, B]
        emitted = np.asarray(out.emitted)      # [K, B]
        self.last_token = np.asarray(out.token).astype(np.int32)
        self.pos = np.asarray(out.pos).astype(np.int32)
        self.n_emitted = np.asarray(out.n_emitted).astype(np.int32)
        self.active = np.asarray(out.active).copy()
        self.steps_executed += steps
        finished: List[Request] = []
        for slot in slots:
            req = self.slot_req[slot]
            for k in range(steps):
                if emitted[k, slot]:
                    req.output.append(int(toks[k, slot]))
            if not self.active[slot]:
                self._finish(slot)
                finished.append(req)
        return finished

    def step(self) -> List[Request]:
        """One decode step for all active lanes (a chunk of 1)."""
        return self.step_chunk(1)

    # -- memory accounting (paper Fig. 7) -------------------------------------
    def kv_cache_bytes(self) -> int:
        """Real per-engine KV-cache footprint: K/V pages PLUS the
        representative keys (rep_min/rep_max) and the per-page metadata
        arrays (priority / page_pos / page_len / pinned / active_slot /
        cur_len) — everything the paged cache allocates per lane."""
        total = 0
        for pos_cache in self.cache.per_pos:
            if pos_cache.attn is None:
                continue
            total += sum(x.nbytes for x in jax.tree.leaves(pos_cache.attn))
        return total
