"""Batched serving engine with slot-based continuous batching.

The paper's deployment story: a decode-dominated engine where each
sequence's KV cache is a *fixed-size* RaaS-managed region (O(L) per
slot), so the engine's total memory is ``batch_slots * L`` regardless
of how long any chain-of-thought runs — this is the "significantly
higher throughput" claim of paper §4.3.

Design:
  * ``batch_slots`` fixed decode lanes; the scheduler (scheduler.py)
    assigns queued requests to free lanes.
  * Prefill runs one request at a time (prompts padded to
    ``max_prefill``), its cache rows are spliced into the lane.
  * One jitted ``decode_step`` advances every active lane; finished
    lanes (EOS or max_new_tokens) are freed.
  * Greedy sampling (the paper's math evals are greedy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RaasConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, raas: RaasConfig,
                 batch_slots: int = 4, max_seq: int = 1024,
                 max_prefill: int = 128, impl: str = "jnp",
                 param_dtype=jnp.float32):
        if raas.policy == "quest_raas" and raas.prefill_pages_hint == 0:
            raas = dataclasses.replace(
                raas,
                prefill_pages_hint=-(-max_prefill // raas.page_size))
        self.params = params
        self.cfg = cfg
        self.raas = raas
        self.B = batch_slots
        self.max_seq = max_seq
        self.max_prefill = max_prefill
        self.impl = impl

        self.cache = M.init_model_cache(cfg, raas, batch_slots, max_seq,
                                        prefill_len=max_prefill,
                                        dtype=param_dtype)
        self._fresh_row = M.init_model_cache(cfg, raas, 1, max_seq,
                                             prefill_len=max_prefill,
                                             dtype=param_dtype)
        self.pos = np.zeros(batch_slots, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.last_token = np.zeros(batch_slots, np.int32)
        self.steps_executed = 0

        raas_cfg, cfg_, impl_ = raas, cfg, impl

        @jax.jit
        def _prefill(params, cache_row, tokens, length):
            return M.prefill(params, cfg_, tokens, length, cache_row,
                             impl=impl_)

        @jax.jit
        def _decode(params, cache, token, pos):
            return M.decode_step(params, cfg_, token, pos, cache,
                                 raas_cfg, impl=impl_)

        self._prefill_fn = _prefill
        self._decode_fn = _decode

    # -- slot management -----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _splice_row(self, slot: int, row_cache) -> None:
        self.cache = jax.tree.map(
            lambda full, row: full.at[:, slot].set(row[:, 0]),
            self.cache, row_cache)

    def admit(self, req: Request) -> None:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        L = min(len(req.prompt), self.max_prefill)
        toks = np.zeros((1, self.max_prefill), np.int32)
        toks[0, :L] = req.prompt[:L]
        row = jax.tree.map(lambda x: x, self._fresh_row)
        row_cache, logits = self._prefill_fn(
            self.params, row, jnp.asarray(toks),
            jnp.asarray([L], jnp.int32))
        self._splice_row(slot, row_cache)
        nxt = int(jnp.argmax(logits[0], axis=-1).reshape(-1)[0])
        self.slot_req[slot] = req
        self.pos[slot] = L
        self.last_token[slot] = nxt
        req.output.append(nxt)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        self.slot_req[slot] = None

    # -- decode ----------------------------------------------------------------
    def step(self) -> None:
        """One decode step for all active lanes."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        token = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos.astype(np.int32))
        self.cache, logits = self._decode_fn(self.params, self.cache,
                                             token, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).reshape(self.B, -1)
        self.steps_executed += 1
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot][0])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_token[slot] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.output) >= req.max_new_tokens
                    or self.pos[slot] >= self.max_seq - 1):
                self._finish(slot)

    # -- memory accounting (paper Fig. 7) -------------------------------------
    def kv_cache_bytes(self) -> int:
        total = 0
        for pos_cache in self.cache.per_pos:
            if pos_cache.attn is None:
                continue
            total += pos_cache.attn.k_pages.nbytes
            total += pos_cache.attn.v_pages.nbytes
        return total
