"""Batched serving engine: chunked prefill + fused chunked decode.

The paper's deployment story: a decode-dominated engine where each
sequence's KV cache is a *fixed-size* RaaS-managed region (O(L) per
slot), so the engine's total memory is ``batch_slots * L`` regardless
of how long any chain-of-thought runs — this is the "significantly
higher throughput" claim of paper §4.3.

Design:
  * ``batch_slots`` fixed lanes; each lane is FREE, PREFILL or DECODE.
    The scheduler (scheduler.py) admits queued requests to free lanes.
  * **Admission is registration only** — no compute, no host-side cache
    copy.  A recycled lane is reset *on device* (metadata cleared; the
    page-length prefix contract makes stale KV bytes dead), and the
    prompt is then ingested by the chunked-prefill dispatches.
  * **Prefill is chunked and batched**: one jitted dispatch of
    ``models.model.prefill_chunk`` feeds up to ``prefill_chunk`` prompt
    tokens into *every* lane currently in the PREFILL phase, each lane
    resuming at its own progress (prompts of any length up to
    ``max_prefill`` — which may be set as high as ``max_seq`` — are
    ingested exactly; the old engine silently truncated them).
    Prefill chunks interleave with decode chunks, so admitting a long
    prompt never stalls lanes that are decoding: their caches are
    frozen by the decode dispatch's lane mask, bit-exactly.
  * When a lane's prefill completes, the dispatch's last-position
    logits yield the first sampled token, and **stopping conditions are
    honored at admission**: an immediate EOS or ``max_new_tokens <= 1``
    finishes the request right there — it never occupies a decode lane.
  * The decode hot path is *chunked*: one jitted dispatch of
    ``models.model.decode_chunk`` advances every decode-active lane by
    up to ``chunk_steps`` tokens — greedy sampling, EOS / length
    stopping and position bookkeeping all happen on device, and the
    host only syncs at chunk boundaries (where the scheduler admits /
    frees lanes).  Inactive lanes are frozen in place.
  * Models with SSM (mamba) mixers, MoE FFNs or multi-codebook heads
    fall back to a one-shot prefill per admission (SSM chunk-resume
    state is not carried yet, and MoE expert capacity couples lanes —
    see the ``chunked_prefill`` gate); everything else behaves
    identically.
  * **Sharded serving**: pass ``mesh`` (or set ``ServeConfig.mesh``)
    and every dispatch runs as a jitted computation under the mesh
    with explicit ``NamedSharding``\\ s — the lane axis of the paged
    cache (lane-major page-major ``[B, KV, S, P, hd]``: axis 0), the
    lane phase/progress tables and the decode token buffers shard
    across the "data" axis, params shard per the decode rule table
    over "model" (:mod:`repro.launch.shardings` engine mode).  The
    host-side scheduler is unchanged; host mirrors stay per-lane numpy
    slices, and no dispatch ever gathers the full cache — per-device
    paged-cache bytes are O(L * B / n_data), asserted by
    :meth:`kv_cache_bytes_per_device`.  Outputs are byte-identical to
    the single-device engine (lane math is elementwise along the lane
    axis; with model=1 no reduction is reassociated).
  * **Resilience** (:mod:`repro.serving.resilience`): every request
    ends in a terminal status; transient dispatch failures retry with
    bounded backoff; a decode chunk whose logits go non-finite
    quarantines only the poisoned lane (the on-device ``ok`` mask
    rides the chunk output — no extra transfer); and a decoding lane
    can be checkpointed to host (:meth:`Engine.checkpoint_lane` — one
    snapshot dispatch, one transfer) and restored byte-identically
    onto ANY free lane (:meth:`Engine.restore_lane`), which is what
    the scheduler's graceful degradation and crash recovery stand on.
  * All policy semantics dispatch through the resolved
    :class:`SparsityPolicy` object; the engine knows no policy names.

Accounting is honest: ``tokens_emitted`` counts tokens actually
emitted (from the device-side ``emitted`` mask — a chunk whose lanes
all finish mid-chunk contributes only the real tokens), and
``steps_executed`` counts scan steps in which at least one lane was
live.  ``dispatches`` / ``prefill_dispatches`` count jitted decode /
prefill dispatches issued; ``traces`` counts compilations of the chunk
function (the trace-count test asserts chunks hit the jit cache).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ATTN, ModelConfig, RaasConfig, ServeConfig
from repro.core import page_pool as pool
from repro.core import paged_cache as pc
from repro.core.policy_base import get_policy
from repro.kernels import ops
from repro.models import model as M
from repro.serving import resilience as R

FREE, PREFILL, DECODE = 0, 1, 2


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def prefill_ctx_pages(need_tokens: int, page_size: int,
                      prefill_pages: int) -> int:
    """The ``ctx_pages`` bucket a prefill dispatch runs with: enough
    pages to cover ``need_tokens``, rounded up to the next power of two
    and capped at the lane capacity.  The single source of the
    engine's bucketing policy — the fig7 prefill-traffic sweep imports
    it so its published buckets can never drift from the engine's."""
    return min(prefill_pages, _next_pow2(-(-need_tokens // page_size)))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # multi-turn conversation id (page_pool.generate_session_id): a
    # follow-up request that resends the conversation with the same id
    # resumes the parked KV of the prior turn instead of re-prefilling
    # it.  Each turn is a FRESH Request object carrying the same id.
    session_id: Optional[str] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # terminal status (repro.serving.resilience): None while in
    # flight, then exactly one of OK / REJECTED / FAILED_NAN /
    # FAILED_DISPATCH / PREEMPTED_RESUMED.
    status: Optional[str] = None
    # the request was checkpointed to host (preemption) or replayed
    # after a lane loss at least once; a clean finish then reports
    # PREEMPTED_RESUMED instead of OK.
    preempted: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, raas: RaasConfig,
                 serve: Optional[ServeConfig] = None, *,
                 batch_slots: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 max_prefill: Optional[int] = None, impl: str = "jnp",
                 param_dtype=jnp.float32,
                 chunk_steps: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 mesh=None, faults: Optional[R.FaultPlan] = None):
        geometry = (batch_slots, max_seq, max_prefill, chunk_steps,
                    prefill_chunk)
        if serve is None:
            batch_slots = 4 if batch_slots is None else batch_slots
            max_seq = 1024 if max_seq is None else max_seq
            max_prefill = 128 if max_prefill is None else max_prefill
            serve = ServeConfig(
                batch_slots=batch_slots, max_seq=max_seq,
                max_prefill=max_prefill,
                chunk_steps=8 if chunk_steps is None else chunk_steps,
                prefill_chunk=(min(64, max_prefill) if prefill_chunk is None
                               else prefill_chunk))
        elif any(g is not None for g in geometry):
            raise ValueError(
                "pass either a ServeConfig or the individual geometry "
                "kwargs, not both — mixed styles would silently ignore "
                "the kwargs")
        self.policy = get_policy(raas.policy)
        raas = self.policy.finalize_config(raas, serve.max_prefill)
        self.params = params
        self.cfg = cfg
        self.raas = raas
        self.serve_cfg = serve
        self.B = serve.batch_slots
        self.max_seq = serve.max_seq
        self.max_prefill = serve.max_prefill
        self.impl = impl
        self.chunk_steps = serve.chunk_steps
        # non-final chunks must stay page-aligned: round up to a page
        self.prefill_chunk = -(-serve.prefill_chunk // raas.page_size) \
            * raas.page_size
        # prefill slots are contiguous from slot 0; this static bound is
        # the page capacity of the prefill region.  Per dispatch the
        # region actually attended (``ctx_pages``) is bucketed to the
        # next power of two covering every live lane's progress —
        # a static kernel-grid parameter, so bucketing caps long-prompt
        # ingest at O(log S) compiled prefill variants instead of one
        # per chunk boundary (asserted via ``prefill_traces``).
        self.prefill_pages = -(-serve.max_prefill // raas.page_size)
        # One-shot fallback when chunk-resume can't be lane-exact:
        # SSM state / multi-codebook feeds aren't carried across chunks
        # yet, and MoE expert capacity is assigned over the flattened
        # batch — rider lanes' garbage tokens would compete with active
        # lanes for expert slots, so batched chunked prefill would
        # couple lanes (one-shot prefill runs B=1: no coupling).
        self.chunked_prefill = (
            all(m == "attn" and f != "moe" for m, f in cfg.period)
            and cfg.n_codebooks == 1)
        # Prefix caching / sessions ride the chunked-prefill path only:
        # a mount aliases contiguous prefill-region slots in place,
        # which the one-shot fallback's host-side row splice would
        # clobber (and SSM state has no page identity to alias).
        self.prefix_caching = bool(serve.prefix_caching
                                   and self.chunked_prefill
                                   and cfg.has_attention)
        # admission checks capacity against the *policy's* slot count,
        # not just max_prefill: a lane physically holds n_slots pages.
        self.n_slots = (M.cache_spec(cfg, raas, serve.max_seq,
                                     serve.max_prefill).n_slots
                        if cfg.has_attention else None)

        B = self.B
        if mesh is None and serve.mesh:
            from repro.launch import mesh as mesh_lib
            mesh = mesh_lib.make_serving_mesh(serve.mesh)
        self.mesh = mesh
        self._lane_shd = self._lane2_shd = self._step_shd = None
        cache_shd = None
        def _fresh_cache():
            return M.init_model_cache(cfg, raas, B, self.max_seq,
                                      prefill_len=self.max_prefill,
                                      dtype=param_dtype)

        if mesh is not None:
            from repro.launch import shardings as S
            if not {"data", "model"} <= set(mesh.axis_names):
                raise ValueError(
                    f"serving mesh needs 'data' and 'model' axes, got "
                    f"{mesh.axis_names} (see launch.mesh.make_serving_mesh)")
            if B % mesh.shape["data"]:
                raise ValueError(
                    f"batch_slots={B} must be divisible by the mesh data "
                    f"axis ({mesh.shape['data']}) — ragged lane shards "
                    "would force the partitioner to gather the cache")
            if not self.chunked_prefill:
                raise NotImplementedError(
                    "sharded serving drives the chunked-prefill path; "
                    "SSM / MoE / multi-codebook archs still use the "
                    "one-shot per-lane fallback, which splices a "
                    "single-device row into the sharded cache — run "
                    "these without a mesh until chunk-resume lands")
            # params shard per the decode rule table; engine state —
            # the paged cache's lane axis and every per-lane buffer —
            # shards over "data".
            self.params = jax.device_put(
                params, S.params_shardings(params, cfg, mesh, "engine"))
            self._lane_shd = S.lane_sharding(mesh, B, ndim=1)
            self._lane2_shd = S.lane_sharding(mesh, B, ndim=2)
            self._step_shd = S.lane_sharding(mesh, B, ndim=2, lane_axis=1)
            # the cache is *born sharded*: jit its init with explicit
            # out_shardings so no device ever materializes the full
            # [B, KV, S, P, hd] page array.
            cache_like = jax.eval_shape(_fresh_cache)
            cache_shd = S.engine_state_shardings(cache_like, B, mesh)
            self._cache_init = jax.jit(_fresh_cache,
                                       out_shardings=cache_shd)
        else:
            self._cache_init = _fresh_cache
        self.cache = self._cache_init()
        self._cache_shd = cache_shd
        self.pos = np.zeros(B, np.int32)
        self.phase = np.zeros(B, np.int32)          # FREE/PREFILL/DECODE
        self.slot_req: List[Optional[Request]] = [None] * B
        self._pending_reset = np.zeros(B, bool)     # lanes to recycle
        # page-pool host state: the prefix index, the parked-session
        # map, and the per-lane pending transition queue (flushed as
        # ONE batched dispatch at the next prefill step; a second op
        # on a lane that already has one flushes first, so per-lane
        # op order is exactly the order the engine queued).
        self.pool = pool.PrefixIndex(raas.page_size)
        self.sessions: dict = {}                    # session_id -> lane
        self._lane_session: List[Optional[str]] = [None] * B
        self._pending_op = np.zeros(B, np.int32)    # pool.OP_*
        self._pending_a0 = np.zeros(B, np.int32)
        self._pending_a1 = np.zeros(B, np.int32)
        self._pending_clones: List[tuple] = []      # (src, dst, keep)
        self.prefill_pos = np.zeros(B, np.int32)    # prompt tokens ingested
        self.prompt_len = np.zeros(B, np.int32)
        self.last_token = np.zeros(B, np.int32)
        self.active = np.zeros(B, bool)             # decode-live lanes
        self.n_emitted = np.zeros(B, np.int32)
        self.eos_id = np.full(B, -1, np.int32)
        self.max_new = np.zeros(B, np.int32)
        # admission age per lane (monotone counter): the degradation
        # policy preempts the *youngest* long decode, wasting the least
        # progress of lanes closest to finishing.
        self.lane_seq = np.zeros(B, np.int64)
        self._admit_seq = 0
        # resilience: bounded retry for transient dispatch failures and
        # the (optional) deterministic fault-injection plan.  All
        # injection is host-side at dispatch boundaries — the compiled
        # HLO is identical with or without a plan (audited).
        self.retry_limit = serve.retry_limit
        self.retry_backoff_s = serve.retry_backoff_s
        self._faults = faults
        self.steps_executed = 0     # decode scan steps with >=1 live lane
        self.tokens_emitted = 0     # true emitted tokens (incl. prefill's)
        self.prefill_tokens = 0     # prompt tokens ingested
        self.dispatches = 0         # jitted decode-chunk dispatches
        self.prefill_dispatches = 0  # jitted prefill dispatches
        self.traces = 0             # chunk-fn compilations
        self.prefill_traces = 0     # prefill-chunk-fn compilations
                                    # (bounded by the ctx_pages buckets)
        # prefix-cache accounting: prompt tokens served from resident
        # pages instead of prefill compute, split by mechanism.
        self.prefix_cached_tokens = 0
        self.prefix_mounts = 0      # zero-copy parked-lane mounts
        self.prefix_clones = 0      # busy-donor page copies
        self.session_hits = 0       # mounts that resumed a session
        self.pool_dispatches = 0    # transition + clone dispatches
        # resilience accounting
        self.checkpoints = 0        # lanes snapshotted to host
        self.restores = 0           # checkpoints restored onto a lane
        self.retries = 0            # dispatch attempts retried
        self.nan_quarantines = 0    # lanes quarantined on non-finite logits
        self.lane_losses = 0        # simulated lane losses replayed
        self.tokens_discarded = 0   # emitted tokens dropped by faults
                                    # (tokens_emitted - tokens_discarded
                                    # == sum of surviving outputs)
        # analytic prefill attention traffic (ops.flash_prefill_cost,
        # exact from the kernel grid x the per-dispatch chunk-resume
        # table, summed over attention layers): the paged in-place
        # number actually paid, and what the pre-paged token-major
        # gather path would have paid for the same dispatches.
        self.prefill_kv_bytes = 0
        self.prefill_kv_bytes_gather = 0
        self._n_attn_layers = cfg.n_periods * sum(
            1 for m, _f in cfg.period if m == ATTN)
        self._kv_itemsize = jnp.dtype(param_dtype).itemsize

        raas_cfg, cfg_, impl_, policy = raas, cfg, impl, self.policy

        # explicit NamedShardings on every dispatch under a mesh: the
        # cache stays lane-sharded across calls (never re-laid-out by
        # the partitioner, never gathered), and chunk outputs come back
        # lane-sharded so the host only ever transfers the small [K, B]
        # token/emitted arrays.
        def _out(*shd):
            if mesh is None:
                return {}
            return {"out_shardings": shd[0] if len(shd) == 1 else shd}

        def _reset(cache, mask):
            # leaves are period-stacked [n_periods, B, ...]: align the
            # lane mask with axis 1, not the leading period axis.
            return M.ModelCache(per_pos=tuple(
                bc._replace(
                    attn=None if bc.attn is None
                    else pc.reset_lanes(bc.attn, mask),
                    mamba=None if bc.mamba is None
                    else jax.tree.map(
                        lambda x: jnp.where(
                            mask.reshape((1, -1) + (1,) * (x.ndim - 2)),
                            jnp.zeros_like(x), x), bc.mamba))
                for bc in cache.per_pos))

        def _scrub(cache, mask):
            # quarantine companion to _reset: zero the masked lanes'
            # page payload.  reset_lanes is metadata-only — sound for
            # finite stale bytes, not for the NaN/Inf ones a poisoned
            # lane holds (see paged_cache.scrub_lanes).
            return M.ModelCache(per_pos=tuple(
                bc._replace(attn=None if bc.attn is None
                            else pc.scrub_lanes(bc.attn, mask))
                for bc in cache.per_pos))

        def _transition(cache, op, a0, a1):
            # metadata-only pool transitions, batched over lanes;
            # mamba is None on the (all-attn) prefix-caching path.
            return M.ModelCache(per_pos=tuple(
                bc._replace(attn=None if bc.attn is None
                            else pool.transition_lanes(bc.attn, op, a0, a1))
                for bc in cache.per_pos))

        def _clone(cache, src, dst, keep):
            return M.ModelCache(per_pos=tuple(
                bc._replace(attn=None if bc.attn is None
                            else pool.clone_prefix(bc.attn, src, dst, keep))
                for bc in cache.per_pos))

        def _snapshot(cache, lane):
            # one lane's rows across every attention block — a single
            # dispatch whose output is the whole device->host transfer
            # of a checkpoint.  The cache is NOT donated: the engine
            # keeps serving the other lanes from it.
            return tuple(None if bc.attn is None
                         else pc.snapshot_lane(bc.attn, lane)
                         for bc in cache.per_pos)

        def _restore(cache, lane, rows):
            return M.ModelCache(per_pos=tuple(
                bc._replace(attn=None if bc.attn is None
                            else pool.restore_lane(bc.attn, lane, row))
                for bc, row in zip(cache.per_pos, rows)))

        def _prefill_chunk(params, cache, tokens, chunk_lens, start,
                           ctx_pages):
            self.prefill_traces += 1    # runs at trace time only
            return M.prefill_chunk(params, cfg_, tokens, chunk_lens,
                                   start, cache, ctx_pages=ctx_pages,
                                   impl=impl_)

        @jax.jit
        def _prefill_oneshot(params, cache, tokens, lengths):
            return M.prefill(params, cfg_, tokens, lengths, cache,
                             impl=impl_)

        def _chunk(params, cache, token, pos, active, n_emitted,
                   eos_id, max_new, steps):
            self.traces += 1        # runs at trace time only
            return M.decode_chunk(params, cfg_, cache, token, pos,
                                  active, n_emitted, eos_id, max_new,
                                  raas_cfg, steps=steps,
                                  max_seq=self.max_seq, impl=impl_,
                                  policy=policy)

        # every chunked dispatch donates its input cache: the engine
        # always rebinds self.cache to the dispatch output, so the old
        # buffer is dead the moment the call is issued — donation lets
        # XLA alias it in place instead of holding cache x2 live
        # (repro.analysis's donation audit enforces this stays true)
        self._reset_fn = jax.jit(_reset, donate_argnums=(0,),
                                 **_out(cache_shd))
        self._scrub_fn = jax.jit(_scrub, donate_argnums=(0,),
                                 **_out(cache_shd))
        self._transition_fn = jax.jit(_transition, donate_argnums=(0,),
                                      **_out(cache_shd))
        self._clone_fn = jax.jit(_clone, donate_argnums=(0,),
                                 **_out(cache_shd))
        # checkpoint/restore ride the chunked attention path (a lane's
        # state is fully captured by its PagedCache rows there; SSM
        # state has no page identity to snapshot).  Restore donates
        # the cache like every other lane transition; snapshot must
        # not — its input cache keeps serving.
        self._snapshot_fn = self._restore_fn = None
        if self.chunked_prefill and cfg.has_attention:
            self._snapshot_fn = jax.jit(_snapshot)
            self._restore_fn = jax.jit(_restore, donate_argnums=(0,),
                                       **_out(cache_shd))
        self._prefill_chunk_fn = jax.jit(
            _prefill_chunk, static_argnames=("ctx_pages",),
            donate_argnums=(1,),
            **_out(cache_shd, self._lane2_shd
                   if mesh is not None else None))
        self._prefill_fn = _prefill_oneshot
        self._chunk_fn = jax.jit(
            _chunk, static_argnames=("steps",),
            donate_argnums=(1,),
            **_out(cache_shd,
                   M.chunk_result_sharding(self._lane_shd, self._step_shd)
                   if mesh is not None else None))
        # one-shot fallback path keeps a single device-resident template
        # row (built once; the jitted one-shot prefill deliberately does
        # NOT donate it — the row is a reusable template spliced into
        # self.cache host-side, so it must survive every admission)
        self._fresh_row = None
        if not self.chunked_prefill:
            self._fresh_row = M.init_model_cache(
                cfg, raas, 1, self.max_seq, prefill_len=self.max_prefill,
                dtype=param_dtype)

    # -- host <-> device -----------------------------------------------------
    def _dev(self, arr) -> jnp.ndarray:
        """One host mirror -> one committed device buffer for a dispatch.

        Always copies (dispatch is async; an in-place host write racing
        a still-running device read is silent corruption — caught by
        the parity tests), and under a mesh commits the buffer to its
        lane sharding so the jitted computation consumes it shard-local
        — no dispatch ever gathers engine state.
        """
        arr = np.asarray(arr).copy()
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(
            arr, self._lane_shd if arr.ndim == 1 else self._lane2_shd)

    # -- resilience ----------------------------------------------------------
    def set_faults(self, plan: Optional[R.FaultPlan]) -> None:
        """Attach (or detach, with None) a fault-injection plan.  Purely
        host-side: the jitted dispatches are untouched, so a shared
        compiled engine can flip plans between test runs."""
        self._faults = plan

    def _dispatch(self, site: str, fn, *args, **kwargs):
        """Issue one jitted dispatch with bounded retry-with-backoff on
        transient failures.

        Injected faults raise *before* ``fn`` is invoked, so a failed
        attempt never consumes donated buffers — retrying with the
        same arguments is always sound.  (A genuinely transient error
        raised from inside a donating dispatch would leave the cache
        consumed; such errors surface as DispatchFailedError on the
        next attempt and the scheduler's drain path rebuilds.)  The
        retry loop is bounded by ``retry_limit`` — see the
        ``no-unbounded-retry`` lint rule.
        """
        last: Optional[BaseException] = None
        for attempt in range(self.retry_limit):
            if attempt:
                self.retries += 1
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (1 << (attempt - 1)))
            try:
                if self._faults is not None \
                        and self._faults.dispatch_error(site):
                    raise R.InjectedFault(
                        f"injected transient {site} failure")
                return fn(*args, **kwargs)
            except R.TransientDispatchError as e:
                last = e
        raise R.DispatchFailedError(
            f"{site} dispatch still failing after {self.retry_limit} "
            "attempts") from last

    # -- slot management -----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i in range(self.B) if self.phase[i] == FREE]

    def has_active(self) -> bool:
        return bool((self.phase != FREE).any())

    def has_prefill_pending(self) -> bool:
        return bool((self.phase == PREFILL).any())

    def admit(self, req: Request) -> None:
        """Register a request on a free lane.  No compute happens here:
        the prompt is ingested by subsequent :meth:`prefill_step`
        dispatches (interleaved with decode), so admission never stalls
        active lanes.  Raises if no lane is free, the request was
        already served, or the prompt exceeds the lane's capacity
        (``max_prefill`` *and* the policy's physical slot count — the
        old engine silently truncated / silently clipped these).

        With prefix caching on, admission consults the prefix index:
        a prompt whose leading pages are parked on a free lane mounts
        them in place (zero-copy — only refcounts move); a busy
        donor's pages are cloned once (O(prefix bytes), no model
        compute); either way prefill resumes at the first un-cached
        token.  A fresh :attr:`Request.session_id` marks the lane for
        parking at finish; a returning id resumes the conversation."""
        if req.done or req.output:
            raise ValueError(
                f"request uid={req.uid} was already served (done={req.done}, "
                f"{len(req.output)} output tokens) — re-admitting would "
                "append to stale output.  Each turn is a fresh Request; "
                "pass the same session_id to resume a conversation.")
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        if self._faults is not None and self._faults.admission_race():
            # simulated concurrent admitter claimed the lane between
            # the free check and registration: same transient
            # RuntimeError a genuinely full engine raises, so the
            # scheduler requeues and retries at the next boundary.
            raise RuntimeError("no free slot (injected admission race)")
        L = len(req.prompt)
        if L > self.max_prefill:
            raise ValueError(
                f"prompt of {L} tokens exceeds the lane prefill capacity "
                f"max_prefill={self.max_prefill} (raise max_prefill — up "
                f"to max_seq={self.max_seq} — to serve longer prompts)")
        if L < 1:
            raise ValueError("empty prompt")
        P = self.raas.page_size
        if self.n_slots is not None and -(-L // P) > self.n_slots:
            raise ValueError(
                f"prompt of {L} tokens needs {-(-L // P)} pages but the "
                f"policy budget provisions only n_slots={self.n_slots} "
                "per lane — ingest would clip; raise budget_tokens or "
                "lower max_prefill")
        sid = None
        if req.session_id is not None:
            sid = pool.validate_session_id(req.session_id)

        slot, keep = None, 0
        if self.prefix_caching:
            slot, keep = self._admit_via_pool(req, sid, free)
        if slot is None:
            slot = free[0]
            # the on-device lane reset is deferred and batched: all
            # lanes admitted at this chunk boundary are recycled in ONE
            # dispatch at the next prefill step.
            if self.prefix_caching:
                self._drop_parked(slot)
                self._queue_op(slot, pool.OP_RESET)
            else:
                self._pending_reset[slot] = True
        self._admit_seq += 1
        self.lane_seq[slot] = self._admit_seq
        self.slot_req[slot] = req
        self.phase[slot] = PREFILL
        self.prefill_pos[slot] = keep
        self.prompt_len[slot] = L
        self.active[slot] = False
        self.eos_id[slot] = -1 if req.eos_id is None else req.eos_id
        self.max_new[slot] = req.max_new_tokens

    # -- page-pool admission ---------------------------------------------------
    def _admit_via_pool(self, req: Request, sid: Optional[str],
                        free: List[int]):
        """Pick the lane and cached-prefix length for ``req``.  Returns
        ``(slot, keep_tokens)`` with the mount / clone op queued, or
        ``(None, 0)`` when nothing is cached (caller resets a lane)."""
        P = self.raas.page_size
        L = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        match = self.pool.lookup(prompt)
        if match is None:
            return None, 0
        donor, n_pages = match
        # always leave at least one token to ingest: the final prefill
        # chunk is what samples the request's first token.
        keep = min(n_pages * P, ((L - 1) // P) * P)
        if keep <= 0:
            return None, 0
        if sid is not None and self.sessions.get(sid) == donor:
            self.session_hits += 1
        if self.phase[donor] == FREE:
            # zero-copy: mount the parked pages where they already live
            if keep // P < self.pool.covered_pages(donor):
                self.pool.truncate(donor, keep // P)
            self._consume_session(donor)
            self._queue_op(donor, pool.OP_MOUNT, a0=keep)
            self.prefix_mounts += 1
            slot = donor
        else:
            # busy donor: copy its prefix pages into a free lane once —
            # O(prefix bytes), still no prefill compute for them
            slot = self._pick_lane(free)
            self._drop_parked(slot)
            self._pending_reset[slot] = False
            self._pending_op[slot] = pool.OP_NOP
            self._pending_clones.append((donor, slot, keep))
            self.prefix_clones += 1
        self.prefix_cached_tokens += keep
        return slot, keep

    def _pick_lane(self, free: List[int]) -> int:
        """Prefer free lanes with no parked prefix — parked pages are
        future cache hits; evict them only when every free lane parks."""
        for i in free:
            if self.pool.covered_pages(i) == 0:
                return i
        return free[0]

    def _drop_parked(self, lane: int) -> None:
        """Forget anything parked on ``lane`` (about to be wiped)."""
        self.pool.drop_lane(lane)
        self._consume_session(lane)

    def _consume_session(self, lane: int) -> None:
        sid = self._lane_session[lane]
        if sid is not None:
            self.sessions.pop(sid, None)
            self._lane_session[lane] = None

    def _queue_op(self, lane: int, op: int, a0: int = 0,
                  a1: int = 0) -> None:
        """Queue one pool transition for ``lane``.  A lane admits only
        one pending op: queuing a second flushes the batch first, so
        per-lane ordering is exactly program order."""
        if self._pending_op[lane] != pool.OP_NOP:
            self._flush_pool_ops()
        self._pending_op[lane] = op
        self._pending_a0[lane] = a0
        self._pending_a1[lane] = a1

    def _flush_pool_ops(self) -> None:
        """Apply pending transitions (one batched dispatch) and clones
        (one dispatch each — rare: only busy-donor admissions)."""
        if (self._pending_op != pool.OP_NOP).any():
            self.pool_dispatches += 1
            self.cache = self._transition_fn(
                self.cache, self._dev(self._pending_op),
                self._dev(self._pending_a0), self._dev(self._pending_a1))
            self._pending_op[:] = pool.OP_NOP
            self._pending_a0[:] = 0
            self._pending_a1[:] = 0
        while self._pending_clones:
            src, dst, keep = self._pending_clones.pop(0)
            self.pool_dispatches += 1
            self.cache = self._clone_fn(self.cache, jnp.int32(src),
                                        jnp.int32(dst), jnp.int32(keep))

    def _register_prefix(self, lane: int) -> None:
        """At prefill completion: register the prompt's full pages as a
        shareable prefix and INCREF the newly covered slots (the
        index's claim, released only by eviction of the parked lane)."""
        prev = self.pool.covered_pages(lane)
        new = self.pool.register(lane, np.asarray(
            self.slot_req[lane].prompt, np.int32))
        if new > prev:
            self._queue_op(lane, pool.OP_INCREF, a0=prev, a1=new)

    def _contiguous_pages(self, lane: int) -> int:
        """Full pages of ``lane`` that sit in slot == position order —
        the resumable prefix.  Decode pages stay contiguous until the
        first real eviction, so this is usually every full page.  One
        small host transfer; called once per finishing session."""
        attn = next(bc.attn for bc in self.cache.per_pos
                    if bc.attn is not None)
        # stacked leaves [n_periods, B, ...]: layer 0 is authoritative
        ppos = np.asarray(attn.page_pos[0, lane])
        plen = np.asarray(attn.page_len[0, lane])
        cur = int(np.asarray(attn.cur_len[0, lane]))
        P = self.raas.page_size
        n = 0
        while (n + 1) * P <= cur and n < len(ppos) \
                and ppos[n] == n * P and plen[n] == P:
            n += 1
        return n

    def _park_lane(self, lane: int, req: Request) -> None:
        """Release the finishing request's claims; if it carries a
        session id, first extend the lane's registration over the whole
        conversation (prompt + emitted output) so the follow-up turn
        can mount it instead of re-prefilling."""
        sid = req.session_id
        if sid is not None:
            hist = np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.output, np.int32)])
            full = min(len(hist) // self.raas.page_size,
                       self._contiguous_pages(lane))
            prev = self.pool.covered_pages(lane)
            if full > prev:
                new = self.pool.register(
                    lane, hist[:full * self.raas.page_size])
                if new > prev:
                    self._queue_op(lane, pool.OP_INCREF, a0=prev, a1=new)
            self._consume_session(lane)
            self.sessions[sid] = lane
            self._lane_session[lane] = sid
        self._queue_op(lane, pool.OP_RELEASE)

    def _finish(self, slot: int) -> Request:
        req = self.slot_req[slot]
        if self.prefix_caching:
            self._park_lane(slot, req)
        req.done = True
        if req.status is None:
            req.status = R.PREEMPTED_RESUMED if req.preempted else R.OK
        self.slot_req[slot] = None
        self.phase[slot] = FREE
        self.active[slot] = False
        return req

    def _fail_lane(self, slot: int, status: str) -> Request:
        """Quarantine ``slot``: terminal-fail its request and recycle
        the lane WITHOUT parking anything (its pages may hold poisoned
        bytes), dropping any parked claims it carried.  The other
        lanes are untouched — lane math is elementwise on the lane
        axis, so a poisoned lane cannot corrupt the batch."""
        req = self.slot_req[slot]
        if status == R.FAILED_NAN:
            self.nan_quarantines += 1
            if self.cfg.has_attention and self.chunked_prefill:
                # the lane's pages really may hold NaN/Inf bytes, and
                # the metadata-only reset leaves payload in place —
                # scrub it, or the next request recycled onto this
                # lane inherits the poison through masked reductions.
                mask = np.zeros(self.B, bool)
                mask[slot] = True
                self.cache = self._scrub_fn(self.cache, self._dev(mask))
        if self.prefix_caching:
            self._drop_parked(slot)
            self._queue_op(slot, pool.OP_RESET)
        else:
            self._pending_reset[slot] = True
        req.done = True
        req.status = status
        self.slot_req[slot] = None
        self.phase[slot] = FREE
        self.active[slot] = False
        return req

    def _lose_lane(self, slot: int) -> Optional[Request]:
        """Simulated lane loss (FaultPlan): the lane's device state is
        declared gone mid-flight.  With no checkpoint to restore from,
        recovery is replay: emitted output is discarded (counted in
        ``tokens_discarded``) and the request re-admitted through the
        normal path — greedy decode regenerates the same tokens, so
        the replayed output is byte-identical to the lost run's.
        Returns the request only if replay admission was raced out and
        it had to be failed terminally (the caller reports it done)."""
        req = self.slot_req[slot]
        if req is None:
            return None
        self.lane_losses += 1
        self.tokens_discarded += len(req.output)
        if self.prefix_caching:
            self._drop_parked(slot)
            self._queue_op(slot, pool.OP_RESET)
        else:
            self._pending_reset[slot] = True
        self.slot_req[slot] = None
        self.phase[slot] = FREE
        self.active[slot] = False
        self.n_emitted[slot] = 0
        req.output.clear()
        req.preempted = True
        # re-admit onto the freed lane; an injected admission race can
        # steal it a bounded number of times before the request is
        # failed terminally rather than stranded without a status.
        for _ in range(4):
            try:
                self.admit(req)
                return None
            except RuntimeError:
                continue
        req.done = True
        req.status = R.FAILED_DISPATCH
        return req

    # -- lane checkpoint / restore (preemption + crash recovery) --------------
    def flush_pending(self) -> None:
        """Apply deferred lane resets and pool transitions NOW.  They
        are normally batched into the next prefill step; checkpoint/
        restore, the refcount audit and the abort path need the device
        state current before they read or overwrite it."""
        if self.prefix_caching:
            self._flush_pool_ops()
        if self._pending_reset.any():
            self.cache = self._reset_fn(
                self.cache, self._dev(self._pending_reset))
            self._pending_reset[:] = False

    def checkpoint_lane(self, slot: int) -> R.LaneCheckpoint:
        """Snapshot lane ``slot``'s complete serving state to host and
        free the lane.

        One snapshot dispatch, one device->host transfer: the lane's
        pages, representative keys and slot metadata (as PagedCache
        rows per attention block) plus the engine's per-lane progress
        mirrors.  The lane is then released *through the pool*, so
        slots the prefix index claims stay parked for future mounts —
        only the preempted request's own claims drop.  Restore with
        :meth:`restore_lane` onto any free lane, later and elsewhere.

        Only DECODE-phase lanes checkpoint: a mid-prefill lane may
        have mount/clone ops still queued against it — let its prefill
        chunk land first (lane loss, by contrast, replays from
        scratch and handles any phase)."""
        if self._snapshot_fn is None:
            raise NotImplementedError(
                "lane checkpoint/restore rides the chunked-prefill "
                "attention path; SSM / MoE / multi-codebook archs "
                "have engine state outside the paged cache")
        req = self.slot_req[slot]
        if req is None or self.phase[slot] != DECODE:
            raise ValueError(
                f"lane {slot} is not in decode (phase="
                f"{int(self.phase[slot])}) — only decode-phase lanes "
                "checkpoint")
        rows = self._snapshot_fn(self.cache, jnp.int32(slot))
        rows = jax.tree.map(np.asarray, rows)   # ONE host transfer
        ckpt = R.LaneCheckpoint(
            request=req, rows=rows, phase=int(self.phase[slot]),
            pos=int(self.pos[slot]),
            prefill_pos=int(self.prefill_pos[slot]),
            prompt_len=int(self.prompt_len[slot]),
            last_token=int(self.last_token[slot]),
            n_emitted=int(self.n_emitted[slot]),
            eos_id=int(self.eos_id[slot]),
            max_new=int(self.max_new[slot]),
            seq=int(self.lane_seq[slot]),
            n_output=len(req.output))
        req.preempted = True
        self.checkpoints += 1
        # free the lane: the request's claims drop through the pool,
        # so index-claimed slots stay parked (shared prefixes survive
        # the preemption); without a pool the lane is plainly reset.
        if self.prefix_caching:
            self._queue_op(slot, pool.OP_RELEASE)
        else:
            self._pending_reset[slot] = True
        self.slot_req[slot] = None
        self.phase[slot] = FREE
        self.active[slot] = False
        return ckpt

    def restore_lane(self, ckpt: R.LaneCheckpoint,
                     slot: Optional[int] = None) -> int:
        """Restore a checkpointed lane onto ``slot`` (default: any
        free lane) and resume decoding byte-identically.

        One restore dispatch overwrites every cache row of the target
        lane (parked claims on it are dropped first) with the
        checkpoint's rows; the refcount is re-stamped to the restored
        request's single claim (see ``page_pool.restore_lane``).
        Returns the lane the request resumed on."""
        if self._restore_fn is None:
            raise NotImplementedError(
                "lane checkpoint/restore rides the chunked-prefill "
                "attention path")
        free = self.free_slots()
        if slot is None:
            if not free:
                raise RuntimeError("no free slot to restore into")
            slot = self._pick_lane(free)
        elif self.phase[slot] != FREE:
            raise ValueError(f"lane {slot} is not free")
        req = ckpt.request
        if req.done or len(req.output) != ckpt.n_output:
            raise ValueError(
                f"stale checkpoint for uid={req.uid}: the request "
                "advanced or finished since it was taken")
        if self.prefix_caching:
            self._drop_parked(slot)
        # apply queued transitions (the checkpoint's own RELEASE may
        # still be pending) and lane resets before overwriting rows —
        # a reset queued against this lane must not wipe the restore.
        self.flush_pending()
        self.cache = self._restore_fn(self.cache, jnp.int32(slot),
                                      ckpt.rows)
        self.restores += 1
        self._admit_seq += 1                 # monotone counter reuse
        self.lane_seq[slot] = ckpt.seq       # keep the original age
        self.slot_req[slot] = req
        self.phase[slot] = ckpt.phase
        self.pos[slot] = ckpt.pos
        self.prefill_pos[slot] = ckpt.prefill_pos
        self.prompt_len[slot] = ckpt.prompt_len
        self.last_token[slot] = ckpt.last_token
        self.n_emitted[slot] = ckpt.n_emitted
        self.eos_id[slot] = ckpt.eos_id
        self.max_new[slot] = ckpt.max_new
        self.active[slot] = True
        return slot

    def preempt_victim(self, min_emitted: int = 1) -> Optional[int]:
        """The degradation policy's victim: the *youngest* decode lane
        (most recently admitted) that has emitted at least
        ``min_emitted`` tokens — preempting the youngest wastes the
        least progress of the lanes closest to finishing.  None when
        no lane qualifies (e.g. everything is still mid-prefill)."""
        best = None
        for i in range(self.B):
            if self.phase[i] == DECODE \
                    and self.n_emitted[i] >= min_emitted \
                    and (best is None
                         or self.lane_seq[i] > self.lane_seq[best]):
                best = i
        return best

    def abort_in_flight(self,
                        status: str = R.FAILED_DISPATCH) -> List[Request]:
        """Drain every occupied lane after a serve-loop failure:
        terminal-fail the requests (partial output retained), release
        the lanes and their pool claims, and leave the engine
        reusable.  If the device path itself is broken (e.g. a
        donating dispatch died mid-call and consumed the cache), fall
        back to rebuilding the cache from scratch — parked prefixes
        are lost with it, but no claim leaks."""
        aborted: List[Request] = []
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None:
                continue
            req.done = True
            req.status = status
            aborted.append(req)
            if self.prefix_caching:
                self._drop_parked(slot)
                self._queue_op(slot, pool.OP_RESET)
            else:
                self._pending_reset[slot] = True
            self.slot_req[slot] = None
            self.phase[slot] = FREE
            self.active[slot] = False
        try:
            self.flush_pending()
        except Exception:
            # device state is unusable: rebuild fresh.  Not a bare
            # swallow — the recovery below IS the handler.
            self.pool = pool.PrefixIndex(self.raas.page_size)
            self.sessions.clear()
            self._lane_session = [None] * self.B
            self._pending_op[:] = pool.OP_NOP
            self._pending_a0[:] = 0
            self._pending_a1[:] = 0
            self._pending_clones.clear()
            self._pending_reset[:] = False
            self.cache = self._cache_init()
        return aborted

    def audit_refcounts(self) -> dict:
        """Post-drain pool-claim audit: with every lane FREE, a slot's
        refcount must be exactly 1 on the parked pages the prefix
        index claims (``[0, covered_pages(lane))``) and 0 everywhere
        else — anything else is a leaked or lost claim.  Raises
        ``AssertionError`` with the offending state; returns the
        parked-claim accounting.  One host transfer."""
        if (self.phase != FREE).any():
            raise AssertionError(
                "refcount audit requires a drained engine (lanes "
                f"{[i for i in range(self.B) if self.phase[i] != FREE]} "
                "are still occupied)")
        if not (self.chunked_prefill and self.cfg.has_attention):
            return {"skipped": "no paged attention cache to audit"}
        if not self.prefix_caching:
            # without a pool nothing parks: finished lanes keep stale
            # (dead) rows until recycled at admission — reset them all
            # so the audit's zero-claim expectation is meaningful.
            self._pending_reset[:] = True
        self.flush_pending()
        attn = next(bc.attn for bc in self.cache.per_pos
                    if bc.attn is not None)
        rc = np.asarray(attn.refcount)       # [n_periods, B, S] or [B, S]
        rc = rc.reshape((-1,) + rc.shape[-2:])
        if not (rc == rc[0]).all():
            raise AssertionError(
                "refcount diverged across stacked layers — slot "
                "metadata must evolve identically everywhere")
        expect = np.zeros_like(rc[0])
        for lane in range(self.B):
            cover = self.pool.covered_pages(lane) \
                if self.prefix_caching else 0
            expect[lane, :cover] = 1
        if not (rc[0] == expect).all():
            raise AssertionError(
                f"leaked pool claims — refcounts\n{rc[0]}\n!= parked "
                f"claims\n{expect}")
        return {"parked_claims": int(expect.sum()),
                "lanes_parked": int((expect.sum(axis=1) > 0).sum())}

    # -- prefill ---------------------------------------------------------------
    def _start_decode(self, slot: int, nxt: int) -> Optional[Request]:
        """Record the first sampled token of a completed prefill and
        honor stopping conditions *at admission*: a request that is
        already done (immediate EOS / exhausted budget / sequence cap)
        frees its lane without ever entering decode.  Returns the
        request if it finished here, else None."""
        req = self.slot_req[slot]
        plen = int(self.prompt_len[slot])
        if req.max_new_tokens < 1:
            return self._finish(slot)
        req.output.append(nxt)
        self.tokens_emitted += 1
        self.n_emitted[slot] = 1
        hit_eos = req.eos_id is not None and nxt == req.eos_id
        if hit_eos or req.max_new_tokens <= 1 or plen >= self.max_seq - 1:
            return self._finish(slot)
        self.phase[slot] = DECODE
        self.active[slot] = True
        self.last_token[slot] = nxt
        self.pos[slot] = plen
        return None

    def prefill_step(self) -> List[Request]:
        """Ingest one prompt chunk into every lane in the PREFILL phase
        (one batched jitted dispatch); lanes whose prompt completes
        switch to decode — or finish immediately if a stopping
        condition already holds.  Returns the requests finished at
        admission."""
        lanes = [i for i in range(self.B) if self.phase[i] == PREFILL]
        if not lanes:
            return []
        if not self.chunked_prefill:
            # the one-shot splice overwrites every leaf of the lane, so
            # no reset dispatch is needed on the fallback path
            self._pending_reset[:] = False
            return self._prefill_oneshot_step(lanes)
        if self.prefix_caching:
            # apply queued pool transitions (mount/reset/incref/release)
            # and any busy-donor prefix clones before touching lanes
            self._flush_pool_ops()
        if self._pending_reset.any():
            self.cache = self._reset_fn(
                self.cache, self._dev(self._pending_reset))
            self._pending_reset[:] = False
        C = self.prefill_chunk
        toks = np.zeros((self.B, C), np.int32)
        chunk_lens = np.zeros(self.B, np.int32)
        for i in lanes:
            got = int(self.prefill_pos[i])
            n = min(C, int(self.prompt_len[i]) - got)
            toks[i, :n] = self.slot_req[i].prompt[got:got + n]
            chunk_lens[i] = n
        self.prefill_dispatches += 1
        self.prefill_tokens += int(chunk_lens.sum())
        # the region this dispatch attends: enough pages to cover every
        # live lane's post-chunk progress, bucketed to the next power
        # of two (capped at the lane capacity) so a prompt of any
        # length hits at most O(log prefill_pages) compiled variants.
        P = self.raas.page_size
        need = int((self.prefill_pos + chunk_lens)[chunk_lens > 0].max())
        ctx_pages = prefill_ctx_pages(need, P, self.prefill_pages)
        self._account_prefill_bytes(chunk_lens, ctx_pages)
        # every host mirror goes through _dev: defensive copy (dispatch
        # is async) + lane sharding under a mesh.
        self.cache, logits = self._dispatch(
            "prefill_chunk", self._prefill_chunk_fn,
            self.params, self.cache, self._dev(toks),
            self._dev(chunk_lens), self._dev(self.prefill_pos),
            ctx_pages=ctx_pages)
        self.prefill_pos += chunk_lens
        finished: List[Request] = []
        done_lanes = [i for i in lanes
                      if self.prefill_pos[i] >= self.prompt_len[i]]
        if done_lanes:
            # one batched argmax + one host transfer per dispatch, not
            # one blocking round-trip per completing lane
            first = np.asarray(jnp.argmax(logits, axis=-1))     # [B]
            fin = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            for i in done_lanes:
                if not fin[i]:
                    # poisoned before its first token: quarantine the
                    # lane, never park the (possibly corrupt) prompt
                    finished.append(self._fail_lane(i, R.FAILED_NAN))
                    continue
                if self.prefix_caching:
                    # the freshly ingested prompt is now shareable
                    self._register_prefix(i)
                req = self._start_decode(i, int(first[i]))
                if req is not None:
                    finished.append(req)
        return finished

    def _account_prefill_bytes(self, chunk_lens: np.ndarray,
                               ctx_pages: int) -> None:
        """Accumulate the dispatch's analytic attention traffic: the
        paged kernel's exact bytes (``prefill_kv_bytes``) and what the
        pre-paged token-major gather would have paid for the same
        dispatch (``prefill_kv_bytes_gather`` = kernel + O(ctx)
        materialization per layer) — the benchmark's
        ``prefill_bytes_per_token`` numerator."""
        P = self.raas.page_size
        C = self.prefill_chunk
        bQ, ppb = ops.paged_prefill_geometry(C, ctx_pages, P)
        cost = ops.flash_prefill_cost(
            H=self.cfg.n_heads, KV=self.cfg.n_kv_heads,
            hd=self.cfg.resolved_head_dim, Sq=C,
            ctx_tokens=ctx_pages * P,
            q_offset=self.prefill_pos,
            kv_len=np.where(chunk_lens > 0,
                            self.prefill_pos + chunk_lens, 0),
            block_q=bQ, block_kv=ppb * P, itemsize=self._kv_itemsize)
        n = self._n_attn_layers
        self.prefill_kv_bytes += cost["bytes_accessed"] * n
        self.prefill_kv_bytes_gather += (
            cost["bytes_accessed"] + cost["gather_bytes"]) * n

    def _prefill_oneshot_step(self, lanes: List[int]) -> List[Request]:
        """Fallback for SSM / multi-codebook models: one-shot prefill
        into a template row, spliced into the lane."""
        finished: List[Request] = []
        for slot in lanes:
            req = self.slot_req[slot]
            L = int(self.prompt_len[slot])
            toks = np.zeros((1, self.max_prefill), np.int32)
            toks[0, :L] = req.prompt
            self.prefill_dispatches += 1
            self.prefill_tokens += L
            row_cache, logits = self._prefill_fn(
                self.params, self._fresh_row, jnp.asarray(toks),
                jnp.asarray([L], jnp.int32))
            self.cache = jax.tree.map(
                lambda full, row: full.at[:, slot].set(row[:, 0]),
                self.cache, row_cache)
            self.prefill_pos[slot] = L
            # axis=-1 keeps multi-codebook logits [C, V] sampling a
            # codebook-0 token id, not a flattened [C*V] index
            nxt = int(jnp.argmax(logits[0], axis=-1).reshape(-1)[0])  # analysis: allow=host-sync-in-dispatch-loop -- one-shot fallback runs one prefill dispatch per lane; this sync matches dispatch granularity
            req2 = self._start_decode(slot, nxt)
            if req2 is not None:
                finished.append(req2)
        return finished

    def drain_prefill(self) -> List[Request]:
        """Run prefill dispatches until no lane is mid-prefill (test /
        sequential-baseline convenience; the continuous-batching loop
        interleaves single :meth:`prefill_step` calls with decode
        instead).  Returns the requests finished at admission."""
        finished: List[Request] = []
        while self.has_prefill_pending():
            finished.extend(self.prefill_step())
        return finished

    # -- decode ----------------------------------------------------------------
    def step_chunk(self, steps: Optional[int] = None) -> List[Request]:
        """Advance every decode-active lane by up to ``steps`` tokens in
        ONE jitted dispatch; sync host state at the boundary and free
        finished lanes.  Lanes mid-prefill (and finished lanes) are
        frozen by the on-device lane mask.  Returns the requests that
        finished."""
        steps = self.chunk_steps if steps is None else steps
        slots = [i for i in range(self.B) if self.phase[i] == DECODE]
        if not slots:
            return []
        self.dispatches += 1
        # _dev copies defensively: host mirrors are mutated in place by
        # admission while dispatches may still be in flight.
        self.cache, out = self._dispatch(
            "decode_chunk", self._chunk_fn,
            self.params, self.cache,
            self._dev(self.last_token), self._dev(self.pos),
            self._dev(self.active), self._dev(self.n_emitted),
            self._dev(self.eos_id), self._dev(self.max_new),
            steps=steps)
        toks = np.asarray(out.tokens)          # [K, B]
        emitted = np.asarray(out.emitted)      # [K, B]
        # .copy(): the device view is read-only, and the NaN-injection
        # hook below flips entries of the host-side mask in place.
        ok = np.asarray(out.ok).copy()         # [K, B]
        self.last_token = np.asarray(out.token).astype(np.int32)
        self.pos = np.asarray(out.pos).astype(np.int32)
        self.n_emitted = np.asarray(out.n_emitted).astype(np.int32)
        self.active = np.asarray(out.active).copy()
        # honest accounting: tokens actually emitted, and scan steps in
        # which at least one lane was still live — a chunk whose lanes
        # all finish mid-chunk doesn't inflate tokens/sec.
        self.tokens_emitted += int(emitted.sum())
        self.steps_executed += int(emitted.any(axis=1).sum())
        if self._faults is not None:
            # injected NaN: flip the already-transferred finite mask —
            # exercises the exact quarantine path real non-finite
            # logits take, with zero device-side machinery.
            bad = self._faults.poison_lane(slots)
            if bad is not None:
                ok[:, bad] = False
        finished: List[Request] = []
        for slot in slots:
            req = self.slot_req[slot]
            bad_from = None
            for k in range(steps):
                if not emitted[k, slot]:
                    continue
                if not ok[k, slot]:
                    bad_from = k
                    break
                req.output.append(int(toks[k, slot]))
            if bad_from is not None:
                # non-finite logits: every token from the first bad
                # step on is garbage — discard them and quarantine the
                # lane instead of letting NaN bytes poison the batch.
                self.tokens_discarded += int(emitted[bad_from:, slot].sum())
                finished.append(self._fail_lane(slot, R.FAILED_NAN))
            elif not self.active[slot]:
                finished.append(self._finish(slot))
        if self._faults is not None:
            live = [i for i in range(self.B) if self.phase[i] != FREE]
            lost = self._faults.lane_loss(live)
            if lost is not None:
                failed = self._lose_lane(lost)
                if failed is not None:
                    finished.append(failed)
        return finished

    def step(self) -> List[Request]:
        """One decode step for all active lanes (a chunk of 1)."""
        return self.step_chunk(1)

    # -- memory accounting (paper Fig. 7) -------------------------------------
    def _kv_bytes(self, per_device: bool) -> int:
        return sum(pc.cache_nbytes(pos_cache.attn, per_device)
                   for pos_cache in self.cache.per_pos
                   if pos_cache.attn is not None)

    def kv_cache_bytes(self) -> int:
        """Real per-engine KV-cache footprint: K/V pages PLUS the
        representative keys (rep_min/rep_max) and the per-page metadata
        arrays (priority / page_pos / page_len / pinned / active_slot /
        cur_len) — everything the paged cache allocates per lane.
        Global bytes: under a mesh this is the sum over all devices."""
        return self._kv_bytes(per_device=False)

    def kv_cache_bytes_per_device(self) -> int:
        """Paged-cache bytes resident on ONE device, from the
        addressable-shard shapes of each leaf's ``NamedSharding`` —
        no transfer happens.  Equals :meth:`kv_cache_bytes` on a
        single device and ``kv_cache_bytes / n_data`` under a mesh
        (the lane axis shards evenly; metadata rides along) — the
        O(L * B / n_dev) per-device memory claim, asserted by
        tests/test_sharded_serving.py."""
        return self._kv_bytes(per_device=True)
