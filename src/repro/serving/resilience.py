"""Resilience layer for the serving engine: terminal statuses, lane
checkpoints, and a deterministic fault-injection harness.

RaaS makes long reasoning decodes cheap per token — which makes lanes
long-lived, and a production engine must survive a lane being
preempted, a dispatch failing, or the page pool running dry mid-fleet.
This module holds the host-side vocabulary for that:

**Terminal statuses** — every :class:`~repro.serving.engine.Request`
ends in exactly one of :data:`OK`, :data:`REJECTED`,
:data:`FAILED_NAN`, :data:`FAILED_DISPATCH` or
:data:`PREEMPTED_RESUMED` (``Request.status``); a request is never
silently dropped.

**LaneCheckpoint** — the host image of one preempted lane: the cache
rows from :func:`~repro.core.paged_cache.snapshot_lane` (one
device->host transfer) plus the engine's per-lane progress mirrors.
``Engine.checkpoint_lane`` produces one and frees the lane through the
pool (shared prefix pages stay parked); ``Engine.restore_lane`` writes
it onto *any* free lane and resumes byte-identically — greedy decode
plus an elementwise lane axis means lane identity carries no state.

**FaultPlan** — a seeded, self-contained schedule of injected faults,
consulted by the engine at dispatch boundaries only.  All injection is
host-side: an injected dispatch error raises *before* the jitted call
is issued (donated buffers are never consumed by a failed attempt),
and NaN poisoning flips the already-transferred finite mask — so the
compiled HLO is identical with a plan attached or not, which the
host-transfer analysis pass pins down (zero overhead when off).
``max_consecutive_errors`` is kept below the engine's retry limit and
``max_faults`` bounds total injections, so every seeded plan's serve
run provably terminates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

# terminal request statuses (Request.status)
OK = "OK"                                # completed normally
REJECTED = "REJECTED"                    # refused at admission (capacity)
FAILED_NAN = "FAILED_NAN"                # non-finite logits; lane quarantined
FAILED_DISPATCH = "FAILED_DISPATCH"      # dispatch failed beyond retry
PREEMPTED_RESUMED = "PREEMPTED_RESUMED"  # completed, but was preempted
                                         # (checkpoint/restore or replay)
TERMINAL_STATUSES = frozenset(
    {OK, REJECTED, FAILED_NAN, FAILED_DISPATCH, PREEMPTED_RESUMED})


class TransientDispatchError(RuntimeError):
    """A dispatch failure worth retrying (the engine's bounded
    retry-with-backoff catches exactly this type)."""


class InjectedFault(TransientDispatchError):
    """A fault raised by a :class:`FaultPlan` (always transient)."""


class DispatchFailedError(RuntimeError):
    """A dispatch still failing after the engine's retry budget; the
    scheduler's drain path turns this into FAILED_DISPATCH statuses."""


@dataclasses.dataclass
class LaneCheckpoint:
    """Host image of one preempted lane (see module docstring).

    ``rows`` is the engine cache's pytree of per-lane rows — for the
    chunked attention path, one ``PagedCache`` row container per
    period-stacked block — already on host as numpy.  The scalar
    fields mirror the engine's per-lane host state at the checkpoint.
    """

    request: Any                  # the preempted serving.engine.Request
    rows: Tuple                   # per-block cache rows (host numpy)
    phase: int                    # engine phase at checkpoint (DECODE)
    pos: int
    prefill_pos: int
    prompt_len: int
    last_token: int
    n_emitted: int
    eos_id: int
    max_new: int
    seq: int                      # admission sequence (age ordering)
    n_output: int                 # len(request.output) when taken —
                                  # restore rejects a stale checkpoint


@dataclasses.dataclass
class FaultPlan:
    """Deterministic seeded fault schedule for the serving engine.

    Each probability gates one injection point; draws come from a
    private ``numpy`` generator seeded with ``seed``, consumed in
    engine call order — single-threaded serving makes the whole
    schedule a pure function of (seed, workload).

    Termination guarantees baked in: at most
    ``max_consecutive_errors`` dispatch errors in a row (keep it below
    the engine's ``retry_limit`` so an injected transient always
    clears within the retry budget), and at most ``max_faults`` total
    injections of any kind, after which the plan goes quiet.
    """

    seed: int
    p_dispatch_error: float = 0.0   # transient failure per dispatch attempt
    p_nan: float = 0.0              # poison one decode lane per chunk
    p_lane_loss: float = 0.0        # lose one live lane per chunk boundary
    p_admission_race: float = 0.0   # admission loses its lane to a racer
    max_consecutive_errors: int = 2
    max_faults: int = 32

    def __post_init__(self) -> None:
        for name in ("p_dispatch_error", "p_nan", "p_lane_loss",
                     "p_admission_race"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} is not a probability")
        if self.max_consecutive_errors < 0:
            raise ValueError("max_consecutive_errors must be >= 0")
        if self.max_faults < 0:
            raise ValueError("max_faults must be >= 0")
        self._rng = np.random.default_rng(self.seed)
        self._consecutive = 0
        # per-kind injection counts (tests assert faults really fired)
        self.injected: Dict[str, int] = {
            "dispatch_error": 0, "nan": 0, "lane_loss": 0,
            "admission_race": 0}

    def _fire(self, kind: str, p: float) -> bool:
        if p <= 0.0 or sum(self.injected.values()) >= self.max_faults:
            return False
        hit = bool(self._rng.random() < p)
        if hit:
            self.injected[kind] += 1
        return hit

    def dispatch_error(self, site: str) -> bool:
        """Should this dispatch attempt fail?  ``site`` names the
        dispatch kind (telemetry only; the draw stream is shared)."""
        del site
        if self._consecutive >= self.max_consecutive_errors:
            self._consecutive = 0
            return False
        hit = self._fire("dispatch_error", self.p_dispatch_error)
        self._consecutive = self._consecutive + 1 if hit else 0
        return hit

    def poison_lane(self, lanes: Sequence[int]) -> Optional[int]:
        """Lane whose decode logits this chunk should read as
        non-finite (None = no injection)."""
        if not lanes or not self._fire("nan", self.p_nan):
            return None
        return int(lanes[int(self._rng.integers(len(lanes)))])

    def lane_loss(self, lanes: Sequence[int]) -> Optional[int]:
        """Live lane to declare lost at this chunk boundary (its device
        state is treated as gone; the engine replays the request)."""
        if not lanes or not self._fire("lane_loss", self.p_lane_loss):
            return None
        return int(lanes[int(self._rng.integers(len(lanes)))])

    def admission_race(self) -> bool:
        """Should this admission lose its chosen lane to a simulated
        concurrent admitter?  (Raised as the same transient
        RuntimeError a genuinely full engine produces.)"""
        return self._fire("admission_race", self.p_admission_race)
