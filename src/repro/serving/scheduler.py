"""FIFO request scheduler over the engine's decode lanes.

Continuous batching: whenever a lane frees up and the queue is
non-empty, the next request is prefilled and admitted; decode steps
advance all active lanes together.  This is the standard
vLLM/SGLang-style loop reduced to its essentials — the paper's
contribution (bounded per-lane KV memory) is what makes ``batch_slots``
scale with HBM instead of with the longest chain-of-thought.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List

from repro.serving.engine import Engine, Request


def serve(engine: Engine, requests: Iterable[Request],
          max_steps: int = 100_000) -> List[Request]:
    queue = deque(requests)
    done: List[Request] = []
    pending = list(queue)
    steps = 0
    while (queue or any(r is not None for r in engine.slot_req)) \
            and steps < max_steps:
        while queue and engine.free_slots():
            engine.admit(queue.popleft())
        engine.step()
        steps += 1
        for r in pending:
            if r.done and r not in done:
                done.append(r)
    return done
