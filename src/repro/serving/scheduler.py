"""Continuous-batching loop over the engine's lanes.

vLLM-style chunked-prefill serving reduced to its essentials: each
iteration of the loop is one *chunk boundary* —

  1. **FIFO admission**: free lanes are filled from the queue
     (registration only; no prefill compute, so admission is O(1) and
     never blocks lanes that are decoding);
  2. **one batched prefill-chunk dispatch** feeds the next
     ``prefill_chunk`` prompt tokens into every lane still ingesting
     its prompt, each at its own progress — lanes whose prompt
     completes sample their first token and either start decoding or
     finish right there (stopping conditions honored at admission);
  3. **one fused decode dispatch** advances every decode-active lane by
     up to ``chunk_steps`` tokens; finished lanes are drained and freed.

Prefill and decode thus interleave chunk-for-chunk: a long prompt costs
each decoding lane at most one prefill dispatch of latency per
``chunk_steps`` tokens, instead of stalling the whole engine for the
prompt's full length.  The paper's contribution (bounded per-lane KV
memory) is what makes ``batch_slots`` scale with HBM instead of with
the longest chain-of-thought.

Completion tracking is O(1) per finished request: both dispatch kinds
return the requests they finished (each exactly once — a finished lane
is freed before it can finish again).  ``max_steps`` bounds *executed*
decode scan steps — the loop reads the engine's own
``steps_executed`` counter delta, so chunks whose lanes all finish
early are charged for what they ran, not for the full chunk length.
There is no heuristic step-bound fudge — every loop iteration provably
makes progress (admission, prefill tokens, or decode steps), so the
loop terminates without one.

The loop is mesh-agnostic by construction: it only talks to the engine
through admission, the two dispatch kinds, and host-side lane mirrors,
so a lane-sharded engine (``Engine(..., mesh=...)``) serves the exact
same schedule — and, because lane math is elementwise on the lane
axis, the exact same output bytes — as the single-device engine.
Invariants (FIFO admission order, lane capacity never exceeded, exact
``tokens_emitted`` accounting) are property-tested in
tests/test_scheduler_property.py.

Resilience (:mod:`repro.serving.resilience`): a request the engine
rejects at admission gets a terminal ``REJECTED`` status instead of
crashing the whole fleet; a transient admission race requeues and
retries at the next boundary; and **graceful degradation** — when
admission starves for ``preempt_after`` consecutive chunk boundaries
(page-pool pressure: every lane busy with a long decode), the youngest
long decode is checkpointed to host, its lane recycled for the queue,
and the checkpoint restored (FIFO) once pressure clears.  Any raise
escaping the loop drains the in-flight lanes through
``Engine.abort_in_flight`` (terminal FAILED_DISPATCH statuses, pool
claims released, refcount audit run) before propagating — an exception
never leaves lanes leaked or the engine unusable.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional

from repro.serving import resilience as R
from repro.serving.engine import Engine, Request


def serve(engine: Engine, requests: Iterable[Request],
          max_steps: int = 100_000,
          chunk_steps: Optional[int] = None,
          preempt_after: Optional[int] = None) -> List[Request]:
    """Run ``requests`` to completion.  ``max_steps`` bounds the total
    number of decode scan steps actually executed (``steps_executed``
    delta — exact, not dispatches x chunk); ``chunk_steps`` overrides
    the engine's decode chunk length; ``preempt_after`` overrides
    ``ServeConfig.preempt_after`` (consecutive starved boundaries
    before a long decode is checkpointed to host; 0 = never)."""
    queue = deque(requests)
    done: List[Request] = []
    ckpts: List = []          # preempted checkpoints awaiting restore
    steps_issued = 0
    starved = 0
    chunk = engine.chunk_steps if chunk_steps is None else chunk_steps
    if chunk < 1:
        raise ValueError("chunk_steps must be positive")
    if preempt_after is None:
        preempt_after = engine.serve_cfg.preempt_after
    try:
        while queue or ckpts or engine.has_active():
            admitted = False
            while queue and engine.free_slots():
                req = queue[0]
                try:
                    engine.admit(req)
                except ValueError:
                    # permanent: capacity / validation — terminal
                    # status, the request never occupies a lane
                    queue.popleft()
                    req.done = True
                    req.status = R.REJECTED
                    done.append(req)
                    continue
                except RuntimeError:
                    # transient (admission race): retry next boundary
                    break
                queue.popleft()
                admitted = True
            # graceful degradation: starving = a boundary where the
            # queue head could not be admitted at all
            if queue and not admitted and not engine.free_slots():
                starved += 1
            else:
                starved = 0
            if preempt_after and starved >= preempt_after:
                victim = engine.preempt_victim()
                if victim is not None:
                    ckpts.append(engine.checkpoint_lane(victim))
                    starved = 0
                    continue    # admit into the freed lane first
            # pressure cleared: restore parked checkpoints FIFO into
            # lanes the queue no longer needs
            while ckpts and not queue and engine.free_slots():
                engine.restore_lane(ckpts.pop(0))
            done.extend(engine.prefill_step())
            if steps_issued >= max_steps:
                break
            s0 = engine.steps_executed
            done.extend(engine.step_chunk(chunk_steps))
            steps_issued += engine.steps_executed - s0
    except Exception:
        # never leak lanes or pool claims behind a raise: fail the
        # in-flight and checkpointed requests terminally, release
        # their claims, audit, and re-raise the original error.
        done.extend(engine.abort_in_flight())
        for ck in ckpts:
            ck.request.done = True
            ck.request.status = R.FAILED_DISPATCH
            done.append(ck.request)
        engine.audit_refcounts()
        raise
    return done
