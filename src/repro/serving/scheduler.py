"""FIFO request scheduler over the engine's decode lanes.

Continuous batching at chunk granularity: whenever a lane frees up and
the queue is non-empty, the next request is prefilled and admitted;
then one fused dispatch (``Engine.step_chunk``) advances every active
lane by up to ``chunk_steps`` tokens.  Admission and freeing happen
only at chunk boundaries — between dispatches the device never syncs
to host.  This is the standard vLLM/SGLang-style loop reduced to its
essentials — the paper's contribution (bounded per-lane KV memory) is
what makes ``batch_slots`` scale with HBM instead of with the longest
chain-of-thought.

Completion tracking is O(1) per finished request: ``step_chunk``
returns the requests it finished (each exactly once — a finished lane
is freed before it can finish again).
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional

from repro.serving.engine import Engine, Request


def serve(engine: Engine, requests: Iterable[Request],
          max_steps: int = 100_000,
          chunk_steps: Optional[int] = None) -> List[Request]:
    """Run ``requests`` to completion.  ``max_steps`` bounds the total
    number of decode steps (tokens per lane); ``chunk_steps`` overrides
    the engine's chunk length."""
    queue = deque(requests)
    done: List[Request] = []
    steps = 0
    while (queue or engine.has_active()) and steps < max_steps:
        while queue and engine.free_slots():
            engine.admit(queue.popleft())
        before = engine.steps_executed
        done.extend(engine.step_chunk(chunk_steps))
        steps += max(engine.steps_executed - before, 1)
    return done
