"""Continuous-batching loop over the engine's lanes.

vLLM-style chunked-prefill serving reduced to its essentials: each
iteration of the loop is one *chunk boundary* —

  1. **FIFO admission**: free lanes are filled from the queue
     (registration only; no prefill compute, so admission is O(1) and
     never blocks lanes that are decoding);
  2. **one batched prefill-chunk dispatch** feeds the next
     ``prefill_chunk`` prompt tokens into every lane still ingesting
     its prompt, each at its own progress — lanes whose prompt
     completes sample their first token and either start decoding or
     finish right there (stopping conditions honored at admission);
  3. **one fused decode dispatch** advances every decode-active lane by
     up to ``chunk_steps`` tokens; finished lanes are drained and freed.

Prefill and decode thus interleave chunk-for-chunk: a long prompt costs
each decoding lane at most one prefill dispatch of latency per
``chunk_steps`` tokens, instead of stalling the whole engine for the
prompt's full length.  The paper's contribution (bounded per-lane KV
memory) is what makes ``batch_slots`` scale with HBM instead of with
the longest chain-of-thought.

Completion tracking is O(1) per finished request: both dispatch kinds
return the requests they finished (each exactly once — a finished lane
is freed before it can finish again).  ``max_steps`` bounds *executed*
decode scan steps — the loop reads the engine's own
``steps_executed`` counter delta, so chunks whose lanes all finish
early are charged for what they ran, not for the full chunk length.
There is no heuristic step-bound fudge — every loop iteration provably
makes progress (admission, prefill tokens, or decode steps), so the
loop terminates without one.

The loop is mesh-agnostic by construction: it only talks to the engine
through admission, the two dispatch kinds, and host-side lane mirrors,
so a lane-sharded engine (``Engine(..., mesh=...)``) serves the exact
same schedule — and, because lane math is elementwise on the lane
axis, the exact same output bytes — as the single-device engine.
Invariants (FIFO admission order, lane capacity never exceeded, exact
``tokens_emitted`` accounting) are property-tested in
tests/test_scheduler_property.py.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional

from repro.serving.engine import Engine, Request


def serve(engine: Engine, requests: Iterable[Request],
          max_steps: int = 100_000,
          chunk_steps: Optional[int] = None) -> List[Request]:
    """Run ``requests`` to completion.  ``max_steps`` bounds the total
    number of decode scan steps actually executed (``steps_executed``
    delta — exact, not dispatches x chunk); ``chunk_steps`` overrides
    the engine's decode chunk length."""
    queue = deque(requests)
    done: List[Request] = []
    steps_issued = 0
    chunk = engine.chunk_steps if chunk_steps is None else chunk_steps
    if chunk < 1:
        raise ValueError("chunk_steps must be positive")
    while queue or engine.has_active():
        while queue and engine.free_slots():
            engine.admit(queue.popleft())
        done.extend(engine.prefill_step())
        if steps_issued >= max_steps:
            break
        s0 = engine.steps_executed
        done.extend(engine.step_chunk(chunk_steps))
        steps_issued += engine.steps_executed - s0
    return done
